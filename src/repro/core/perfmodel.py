"""Calibrated analytic performance model for the provisioned storage stack.

This container has no Aries network, PM1725a SSDs, or 288 MPI ranks, so paper
-scale timing cannot be *measured*; it is *modeled*. The functional layer
(`ephemeralfs`, `globalfs`) moves real bytes and proves correctness; this
module predicts bandwidth/latency at the paper's scale from first principles
plus a small set of calibration constants, each tied to a paper observation
(C1..C9 in DESIGN.md §1).

Model structure
---------------
* **Write path**: raw aggregate disk bandwidth x pattern efficiency, with a
  fixed setup overhead that produces the small-size ramp of Figs. 2-3.
  Shared-file efficiency depends on deployment size (chunk-allocation
  serialization on one file object -- calibrated from Fig. 4's logarithmic
  scaling); file-per-process efficiency is flat ~0.93 (C3: "the file system
  is being used at the maximum of its capability").
* **Read path (write-then-read, as IOR runs)**: if the per-node working set
  fits the server DRAM cache, reads are network-bound (cache-served);
  otherwise LRU sequential read-back yields ~zero hits (the tail evicts the
  head before it is read) and reads fall to a cache-thrash disk path --
  the sharp collapse of Fig. 2 at >= 512 MB/proc (C2).
* **Unaligned shared writes** (HACC-IO's 38-byte AoS records): BeeGFS takes a
  moderate penalty (no range locks on its write path); Lustre collapses
  (stripe-lock ping-pong across 288 writers on 2 OSTs) -- C7.
* **Metadata**: per-(fs, op) rate tables calibrated from Tables I-II,
  scaled by metadata-target count; BeeGFS dir-stat is client-cache-served
  (the paper's own explanation of the anomalous 5.3M op/s).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Optional

from .resources import (
    ARIES,
    GB,
    GiB,
    LOCAL_PCIE,
    MiB,
    DiskSpec,
    InterconnectSpec,
    P4500,
    PM1725A,
)

Pattern = Literal["shared", "fpp"]
Op = Literal["write", "read"]

# --------------------------------------------------------------------------
# Empirical multi-stream device profiles (paper §IV-A / §IV-B: vendor numbers
# "do not reflect a real use-case with multiple concurrent streams").
# --------------------------------------------------------------------------
PM1725A_STREAMS = dataclasses.replace(PM1725A)  # paper already gives empirical 6.34/3.2
P4500_STREAMS = dataclasses.replace(P4500, read_bw=4.3 * GB, write_bw=2.93 * GB)

# --------------------------------------------------------------------------
# Calibration constants (source in comment)
# --------------------------------------------------------------------------
# C3: FPP peak 11.96 GB/s over 4x3.2 raw = 0.934.
EFS_FPP_WRITE_EFF = 0.934
# Fig. 4 shared-file write scaling: ~2.36 GB/s @1 node, 7.01 @2, ~9.1 @4
# over raw 6.4/12.8/25.6 -> efficiency by *storage-target* count.
EFS_SHARED_WRITE_EFF = {2: 0.37, 4: 0.548, 8: 0.356}
# Cache-served reads are network-bound with these pattern efficiencies
# (C7 read 9.1 GB/s over 2x10 GB/s Aries injection = 0.455).
EFS_SHARED_READ_EFF = 0.455
EFS_FPP_READ_EFF = 0.55
# C2: cache-thrash read path (eviction interference + random-ish chunk order).
EFS_THRASH_READ_EFF = 0.10
# Fraction of node DRAM actually usable as server cache (OS + daemons).
EFS_CACHE_USABLE_FRAC = 0.85
# C7: HACC unaligned shared write on BeeGFS: 5.3 GB/s vs aligned 7.01.
EFS_UNALIGNED_WRITE_FACTOR = 0.78
# Fixed setup overheads producing the small-size ramp (writes pay chunk
# allocation; reads are cheap to start on BeeGFS, expensive on Lustre where
# the MDS+OST lock round-trips dominate small read-backs -- Fig. 2's
# "even more with 4MB per process" read advantage).
EFS_SHARED_SETUP_S = 0.35
EFS_FPP_SETUP_S = 0.15
EFS_READ_SETUP_S = 0.05

# Lustre (2 OSTs on Dom reach ~6 GB/s write; read ~ half of BeeGFS's 9).
LUSTRE_OST_WRITE_BW = 3.0 * GB
LUSTRE_OST_READ_BW = 2.3 * GB
LUSTRE_SETUP_S = 0.05          # fast, dedicated MDS
LUSTRE_READ_SETUP_S = 0.30
# C7: 288 writers with 38-byte records on 2 OSTs: <=1 GB/s write, <0.4 read.
LUSTRE_UNALIGNED_WRITE_EFF = 0.16
LUSTRE_UNALIGNED_READ_EFF = 0.085

# mdtest calibration tables: ops/s (Tables I and II).
# Dom deployment: 2 metadata targets (1/node x 2 nodes).
EFS_MDTEST_DOM = {
    ("dir", "creation"): 8276.43,
    ("dir", "stat"): 5_301_788.76,   # client-cache-served (paper's explanation)
    ("dir", "removal"): 12967.02,
    ("file", "creation"): 6618.37,
    ("file", "stat"): 144410.46,
    ("file", "read"): 22541.08,
    ("file", "removal"): 8431.71,
    ("tree", "creation"): 2183.40,
    ("tree", "removal"): 125.23,
}
EFS_MDTEST_DOM_MD_TARGETS = 2
EFS_MDTEST_AULT = {
    ("dir", "creation"): 1796.31,
    ("dir", "stat"): 667250.43,
    ("dir", "removal"): 5516.92,
    ("file", "creation"): 5234.87,
    ("file", "stat"): 98888.28,
    ("file", "read"): 22889.51,
    ("file", "removal"): 5929.99,
    ("tree", "creation"): 2754.81,
    ("tree", "removal"): 980.84,
}
LUSTRE_MDTEST_DOM = {
    ("dir", "creation"): 37222.57,
    ("dir", "stat"): 182330.42,
    ("dir", "removal"): 38732.00,
    ("file", "creation"): 22916.15,
    ("file", "stat"): 169140.32,
    ("file", "read"): 45181.55,
    ("file", "removal"): 35985.96,
    ("tree", "creation"): 3310.42,
    ("tree", "removal"): 1298.55,
}
# Ops whose rate scales with metadata-target count (create/remove hit md
# disks; stats are cache-served and do not scale).
_MD_SCALING_OPS = {"creation", "removal", "read"}

# Deployment-time model (C8), solved from:  Ault fresh 4.6 s / warm 1.2 s over
# 8 targets (local docker), Dom 5.37 s over 3 targets/node (Shifter image over
# Aries dominates the base term).
DEPLOY_BASE_S = {"shifter": 3.945, "docker": 0.8}
DEPLOY_PER_TARGET_FRESH_S = 0.475
DEPLOY_PER_TARGET_WARM_S = 0.05


@dataclasses.dataclass(frozen=True)
class FSDeployment:
    """What the perfmodel needs to know about a deployed file system."""

    kind: Literal["ephemeral", "lustre"]
    n_nodes: int                      # storage nodes (or OSS hosts)
    storage_targets: int              # storage disks (or OSTs), total
    md_targets: int
    disk: DiskSpec
    node_dram: float = 64 * GiB
    net: InterconnectSpec = ARIES
    local_client: bool = False        # Ault: client co-located with storage
    mdtest_table: Optional[dict] = None

    @property
    def raw_write_bw(self) -> float:
        if self.kind == "lustre":
            return self.storage_targets * LUSTRE_OST_WRITE_BW
        return self.storage_targets * self.disk.write_bw

    @property
    def raw_read_bw(self) -> float:
        if self.kind == "lustre":
            return self.storage_targets * LUSTRE_OST_READ_BW
        return self.storage_targets * self.disk.read_bw

    @property
    def net_bw(self) -> float:
        """Aggregate server-side injection bandwidth toward clients."""
        if self.local_client:
            return self.n_nodes * LOCAL_PCIE.node_bw
        return self.n_nodes * self.net.node_bw


def dom_efs(n_nodes: int = 2) -> FSDeployment:
    """Paper default: BeeGFS over ``n_nodes`` DataWarp nodes, 1 md : 2 storage."""
    return FSDeployment(
        kind="ephemeral",
        n_nodes=n_nodes,
        storage_targets=2 * n_nodes,
        md_targets=n_nodes,
        disk=PM1725A_STREAMS,
        node_dram=64 * GiB,
        net=ARIES,
        mdtest_table=EFS_MDTEST_DOM,
    )


def dom_lustre() -> FSDeployment:
    return FSDeployment(
        kind="lustre",
        n_nodes=2,
        storage_targets=2,   # 2 OSTs
        md_targets=1,
        disk=PM1725A_STREAMS,  # unused for lustre bw
        net=ARIES,
        mdtest_table=LUSTRE_MDTEST_DOM,
    )


def ault_efs() -> FSDeployment:
    """Paper §IV-B: 1 mgmt disk, 2 metadata disks, 5 storage disks, local client."""
    return FSDeployment(
        kind="ephemeral",
        n_nodes=1,
        storage_targets=5,
        md_targets=2,
        disk=P4500_STREAMS,
        node_dram=376 * GiB,
        net=LOCAL_PCIE,
        local_client=True,
        mdtest_table=EFS_MDTEST_AULT,
    )


@dataclasses.dataclass(frozen=True)
class Workload:
    n_procs: int
    size_per_proc: float              # bytes per process (written and read back)
    pattern: Pattern = "shared"
    aligned: bool = True              # False: HACC-style 38-byte AoS records
    transfer_size: float = 1 * MiB

    @property
    def total_bytes(self) -> float:
        return self.n_procs * self.size_per_proc


@dataclasses.dataclass(frozen=True)
class BWResult:
    bandwidth: float                  # B/s as IOR reports (total/elapsed)
    peak_bandwidth: float             # steady-state (no setup overhead)
    elapsed_s: float
    cache_resident: bool              # read path served from server DRAM?
    bound: str                        # "disk" | "network" | "setup" | "cache-thrash"


def _interp_eff(table: dict[int, float], key: int) -> float:
    """Log-interpolate a {count: efficiency} calibration table."""
    if key in table:
        return table[key]
    ks = sorted(table)
    if key <= ks[0]:
        return table[ks[0]]
    if key >= ks[-1]:
        # Fig. 4: logarithmic growth of absolute bw => efficiency decays ~1/k
        # beyond the calibrated range, floored at 0.25.
        base = table[ks[-1]]
        return max(0.25, base * ks[-1] / key)
    lo = max(k for k in ks if k < key)
    hi = min(k for k in ks if k > key)
    t = (math.log(key) - math.log(lo)) / (math.log(hi) - math.log(lo))
    return table[lo] * (1 - t) + table[hi] * t


def predict_write(w: Workload, d: FSDeployment) -> BWResult:
    if d.kind == "lustre":
        eff = 1.0 if w.aligned else LUSTRE_UNALIGNED_WRITE_EFF
        peak = min(d.raw_write_bw * eff, d.net_bw)
        setup = LUSTRE_SETUP_S
    else:
        if w.pattern == "fpp":
            eff = EFS_FPP_WRITE_EFF
            setup = EFS_FPP_SETUP_S + w.n_procs / _md_rate(d, "file", "creation")
        else:
            eff = _interp_eff(EFS_SHARED_WRITE_EFF, d.storage_targets)
            if not w.aligned:
                eff *= EFS_UNALIGNED_WRITE_FACTOR
            setup = EFS_SHARED_SETUP_S
        peak = min(d.raw_write_bw * eff, d.net_bw)
    elapsed = w.total_bytes / peak + setup
    bw = w.total_bytes / elapsed
    bound = "setup" if setup > 0.5 * elapsed else (
        "network" if peak == d.net_bw else "disk"
    )
    return BWResult(bw, peak, elapsed, cache_resident=False, bound=bound)


def _efs_cache_resident(w: Workload, d: FSDeployment) -> bool:
    per_node = w.total_bytes / d.n_nodes
    return per_node <= EFS_CACHE_USABLE_FRAC * d.node_dram


def predict_read(w: Workload, d: FSDeployment) -> BWResult:
    """Read-back of data just written (IOR's default write-then-read)."""
    if d.kind == "lustre":
        eff = 1.0 if w.aligned else LUSTRE_UNALIGNED_READ_EFF
        peak = min(d.raw_read_bw * eff, d.net_bw)
        elapsed = w.total_bytes / peak + LUSTRE_READ_SETUP_S
        return BWResult(w.total_bytes / elapsed, peak, elapsed, False, "disk")

    resident = _efs_cache_resident(w, d)
    if resident:
        eff = EFS_SHARED_READ_EFF if w.pattern == "shared" else EFS_FPP_READ_EFF
        peak = eff * d.net_bw
        if d.local_client:
            # no network hop; bounded by disk+page-cache reads
            peak = min(d.raw_read_bw * (EFS_FPP_READ_EFF + 0.4), d.net_bw)
            peak = min(peak, d.raw_read_bw * 0.95) if w.pattern == "fpp" else min(
                peak, d.raw_read_bw * 0.75
            )
        bound = "network"
    else:
        # C2: LRU sequential read-back of an over-cache working set -> ~0 hits.
        peak = EFS_THRASH_READ_EFF * d.raw_read_bw
        bound = "cache-thrash"
    elapsed = w.total_bytes / peak + EFS_READ_SETUP_S
    return BWResult(w.total_bytes / elapsed, peak, elapsed, resident, bound)


def predict(w: Workload, d: FSDeployment, op: Op) -> BWResult:
    return predict_write(w, d) if op == "write" else predict_read(w, d)


# --------------------------------------------------------------------------
# Metadata (mdtest)
# --------------------------------------------------------------------------
def _md_rate(d: FSDeployment, target: str, op: str) -> float:
    table = d.mdtest_table
    if table is None:
        table = EFS_MDTEST_DOM if d.kind == "ephemeral" else LUSTRE_MDTEST_DOM
    rate = table[(target, op)]
    if d.kind == "ephemeral" and op in _MD_SCALING_OPS:
        base = EFS_MDTEST_DOM_MD_TARGETS if table is EFS_MDTEST_DOM else d.md_targets
        rate = rate * d.md_targets / base
    return rate


def predict_mdtest(d: FSDeployment) -> dict[tuple[str, str], float]:
    table = d.mdtest_table or (EFS_MDTEST_DOM if d.kind == "ephemeral" else LUSTRE_MDTEST_DOM)
    return {key: _md_rate(d, *key) for key in table}


# --------------------------------------------------------------------------
# Deployment time (C8)
# --------------------------------------------------------------------------
def predict_deploy_time(
    targets_per_node: int,
    *,
    runtime: Literal["shifter", "docker"] = "shifter",
    fresh: bool = True,
) -> float:
    """Services on each node start in parallel; per-node work is serial in its
    targets (format/daemon-start per disk)."""
    per_target = DEPLOY_PER_TARGET_FRESH_S if fresh else DEPLOY_PER_TARGET_WARM_S
    return DEPLOY_BASE_S[runtime] + targets_per_node * per_target


# --------------------------------------------------------------------------
# HACC-IO helpers (§IV-A4)
# --------------------------------------------------------------------------
HACC_PARTICLE_BYTES = 38      # XX,YY,ZZ,VX,VY,VZ,phi (7xf32) + pid (i64) + mask (u16)
HACC_VARS = 9


def hacc_workload(n_procs: int, particles_per_proc: int) -> Workload:
    return Workload(
        n_procs=n_procs,
        size_per_proc=particles_per_proc * HACC_PARTICLE_BYTES,
        pattern="shared",
        aligned=False,
        transfer_size=HACC_PARTICLE_BYTES,
    )


# --------------------------------------------------------------------------
# TPU hardware profile for the roofline analysis (brief-specified constants)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TPUProfile:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_link_bw: float = 50e9           # B/s per link
    hbm_bytes: float = 16 * GiB


TPU_V5E = TPUProfile()
