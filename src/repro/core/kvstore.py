"""EphemeralKV: a second data-manager type on the same provisioning substrate.

The paper's concluding pitch (§VII) is that the mechanism is *generic*:
"a unique container packaging various data management systems ... (parallel
file system, object-based storage, database, key-value store)". This module
proves the abstraction: a hash-partitioned KV store deployed on the same
storage allocations, with the same lifecycle (deploy → use → teardown
deletes everything), the same service model, and the same failure semantics
(optional next-node replica).

Used by the serving stack as a feature/embedding cache tier.
"""

from __future__ import annotations

import hashlib
import os
import shutil
import struct
from typing import Iterator, Optional

from .datamanager import FSError, ServiceInfo
from .resources import StorageNode

_LEN = struct.Struct("<q")


class KVShard:
    """One partition, backed by one storage disk (append-log + index)."""

    def __init__(self, shard_id: int, node_id: str, disk_name: str, path: str):
        self.shard_id = shard_id
        self.node_id = node_id
        self.disk_name = disk_name
        self.path = path
        self.alive = True
        self.index: dict[bytes, tuple[int, int]] = {}
        self.ops = {"put": 0, "get": 0, "delete": 0}
        os.makedirs(path, exist_ok=True)
        self._log = open(os.path.join(path, "log.bin"), "ab+")

    def _check(self) -> None:
        if not self.alive:
            raise FSError(f"kv shard {self.shard_id} is down")

    def put(self, key: bytes, value: bytes) -> None:
        self._check()
        self.ops["put"] += 1
        self._log.seek(0, 2)
        off = self._log.tell()
        self._log.write(_LEN.pack(len(value)))
        self._log.write(value)
        self._log.flush()
        self.index[key] = (off + _LEN.size, len(value))

    def get(self, key: bytes) -> Optional[bytes]:
        self._check()
        self.ops["get"] += 1
        loc = self.index.get(key)
        if loc is None:
            return None
        off, ln = loc
        self._log.seek(off)
        return self._log.read(ln)

    def delete(self, key: bytes) -> bool:
        self._check()
        self.ops["delete"] += 1
        return self.index.pop(key, None) is not None

    def keys(self) -> Iterator[bytes]:
        self._check()
        return iter(list(self.index))

    def close(self) -> None:
        self.alive = False
        self.index.clear()
        try:
            self._log.close()
        except Exception:  # noqa: BLE001
            pass


class EphemeralKV:
    """Job-scoped KV store over the granted storage nodes.

    Layout: every non-metadata disk hosts one shard; keys are partitioned by
    blake2s hash. ``replicate=True`` mirrors each key to the next shard on a
    different node (same failure-domain rule as EphemeralFS mirroring).
    """

    def __init__(
        self,
        storage_nodes: tuple[StorageNode, ...],
        base_dir: str,
        *,
        shards_per_node: int = 2,
        replicate: bool = False,
    ):
        if not storage_nodes:
            raise FSError("need at least one storage node")
        self.base_dir = base_dir
        self.replicate = replicate
        self._torn_down = False
        self.shards: list[KVShard] = []
        for node in storage_nodes:
            if node.n_disks < shards_per_node:
                raise FSError(f"{node.node_id}: fewer disks than shards/node")
            for d in range(shards_per_node):
                self.shards.append(
                    KVShard(
                        len(self.shards),
                        node.node_id,
                        node.disks[d].name,
                        os.path.join(base_dir, node.node_id, f"kv{d}"),
                    )
                )
        if replicate and len({s.node_id for s in self.shards}) < 2:
            raise FSError("replication needs shards on >= 2 nodes")

    # -- partitioning ---------------------------------------------------------
    def _shard_of(self, key: bytes) -> int:
        h = hashlib.blake2s(key).digest()
        return int.from_bytes(h[:4], "little") % len(self.shards)

    def _replica_of(self, shard: int) -> int:
        nid = self.shards[shard].node_id
        n = len(self.shards)
        for step in range(1, n):
            cand = (shard + step) % n
            if self.shards[cand].node_id != nid:
                return cand
        return (shard + 1) % n

    def _check(self) -> None:
        if self._torn_down:
            raise FSError("kv store has been torn down")

    # -- API -----------------------------------------------------------------
    def put(self, key: str | bytes, value: bytes) -> None:
        self._check()
        k = key.encode() if isinstance(key, str) else key
        sid = self._shard_of(k)
        primary = self.shards[sid]
        wrote = False
        if primary.alive:
            primary.put(k, value)
            wrote = True
        elif not self.replicate:
            raise FSError(f"shard {sid} down (no replica)")
        if self.replicate:
            rep = self.shards[self._replica_of(sid)]
            if rep.alive:
                rep.put(k, value)
            elif not wrote:
                raise FSError(f"both replicas of shard {sid} down")

    def get(self, key: str | bytes) -> Optional[bytes]:
        self._check()
        k = key.encode() if isinstance(key, str) else key
        sid = self._shard_of(k)
        primary = self.shards[sid]
        if primary.alive:
            return primary.get(k)
        if self.replicate:
            rep = self.shards[self._replica_of(sid)]
            if rep.alive:
                return rep.get(k)
        raise FSError(f"shard {sid} down")

    def delete(self, key: str | bytes) -> bool:
        self._check()
        k = key.encode() if isinstance(key, str) else key
        sid = self._shard_of(k)
        hit = False
        targets = [sid] + ([self._replica_of(sid)] if self.replicate else [])
        for t in targets:
            if self.shards[t].alive:
                hit = self.shards[t].delete(k) or hit
        return hit

    def scan(self) -> set[bytes]:
        self._check()
        out: set[bytes] = set()
        for s in self.shards:
            if s.alive:
                out.update(s.keys())
        return out

    # -- lifecycle -------------------------------------------------------------
    def services(self) -> list[ServiceInfo]:
        return [
            ServiceInfo("kv-shard", s.node_id, s.disk_name, alive=s.alive)
            for s in self.shards
        ]

    def kill_node(self, node_id: str) -> None:
        found = False
        for s in self.shards:
            if s.node_id == node_id:
                s.alive = False
                found = True
        if not found:
            raise FSError(f"no kv shards on {node_id}")

    def healthy(self) -> bool:
        return not self._torn_down and all(s.alive for s in self.shards)

    def teardown(self) -> None:
        self._torn_down = True
        for s in self.shards:
            s.close()
        shutil.rmtree(self.base_dir, ignore_errors=True)
