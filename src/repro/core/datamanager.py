"""DataManager abstraction (paper §III): anything deployable on granted
storage nodes that exposes file I/O to compute-node clients.

The paper deploys BeeGFS but explicitly frames the mechanism as generic
("parallel file system, but also ... object-based storage or databases in the
future"). We keep the abstraction so `EphemeralFS` (BeeGFS-analogue) and
`GlobalFS` (Lustre-analogue baseline) serve the same client API, and future
managers (KV store, object store) can slot in.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Iterable, Optional

from .resources import StorageNode


@dataclasses.dataclass(frozen=True)
class FileStat:
    path: str
    size: int
    is_dir: bool
    stripe_size: int
    n_targets: int


@dataclasses.dataclass
class ServiceInfo:
    kind: str              # "management" | "metadata" | "storage" | "monitor" | "mds" | "ost"
    node_id: str
    disk_name: str
    alive: bool = True


class FSError(OSError):
    pass


class DataManager(abc.ABC):
    """File-oriented data manager. All paths are absolute ('/a/b')."""

    # -- lifecycle -----------------------------------------------------------
    @abc.abstractmethod
    def services(self) -> list[ServiceInfo]:
        ...

    @abc.abstractmethod
    def teardown(self) -> None:
        """Stop services and delete all data (the paper: on release, services
        are killed and data on disks is deleted)."""

    # -- namespace -----------------------------------------------------------
    @abc.abstractmethod
    def create(self, path: str) -> None: ...

    @abc.abstractmethod
    def mkdir(self, path: str) -> None: ...

    @abc.abstractmethod
    def stat(self, path: str) -> FileStat: ...

    @abc.abstractmethod
    def readdir(self, path: str) -> list[str]: ...

    @abc.abstractmethod
    def unlink(self, path: str) -> None: ...

    @abc.abstractmethod
    def rmdir(self, path: str) -> None: ...

    def exists(self, path: str) -> bool:
        try:
            self.stat(path)
            return True
        except FSError:
            return False

    # -- data ----------------------------------------------------------------
    @abc.abstractmethod
    def write(self, path: str, offset: int, data: bytes) -> int: ...

    @abc.abstractmethod
    def read(self, path: str, offset: int, length: int) -> bytes: ...

    # -- failure injection / health ------------------------------------------
    @abc.abstractmethod
    def kill_node(self, node_id: str) -> None: ...

    @abc.abstractmethod
    def healthy(self) -> bool: ...


def normpath(path: str) -> str:
    if not path.startswith("/"):
        raise FSError(f"path must be absolute: {path!r}")
    parts = [p for p in path.split("/") if p]
    for p in parts:
        if p in (".", ".."):
            raise FSError(f"no relative components allowed: {path!r}")
    return "/" + "/".join(parts)


def parent_of(path: str) -> str:
    p = normpath(path)
    if p == "/":
        return "/"
    return p.rsplit("/", 1)[0] or "/"
