"""Container-compat probes shared by tests, examples, and CI entry points.

The kernel/model/distributed code paths track jax+pallas APIs that have
drifted on some container jax versions (pre-existing at seed; see ROADMAP
"Kernel/model tests"). Anything exercising those APIs — test modules via
``tests/conftest.py``, runnable examples like ``examples/serve_decode.py`` —
should *skip* rather than crash when the APIs are absent, so CI fails only
on real regressions in the storage/orchestration layers. This module is the
single source of truth for that detection.
"""

from __future__ import annotations

JAX_DRIFT_REASON = (
    "jax/pallas API drift on this container's jax (pre-existing at seed): "
    "jax.sharding.AxisType and/or pallas CompilerParams are missing"
)


def jax_api_drifted() -> bool:
    """True when the jax/pallas APIs the kernel+model layers target are
    missing (or jax itself will not import) — callers should self-skip."""
    try:
        import jax
        from jax.experimental.pallas import tpu as pltpu
    except Exception:
        return True
    return not (
        hasattr(jax.sharding, "AxisType") and hasattr(pltpu, "CompilerParams")
    )
