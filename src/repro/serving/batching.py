"""Continuous batching with a prefill/decode phase split.

Models one replica's token loop the way the MaxText MLPerf offline-inference
harness drives a decode engine: a fixed array of KV-cache *slots*, prefill
admission that fills one free slot at a time (prefill has priority — it
bounds TTFT), and global decode steps that advance every active slot by one
token. Requests enter a slot when their prefill finishes and leave the
moment their last token is generated, so the batch composition changes
continuously instead of draining batch-at-a-time.

The engine is pure bookkeeping on the virtual clock — it computes phase
durations and token/occupancy accounting; the :class:`~repro.serving.replica.Replica`
owns the event scheduling around it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from .workload import Request


@dataclasses.dataclass(frozen=True)
class ServingPerf:
    """Replica-level timing model.

    Prefill is compute-bound and roughly linear in prompt tokens; a decode
    step pays a fixed base (kernel launch + sampling) plus a per-active-slot
    term (attention over each sequence's KV cache), so batching raises
    throughput while gently raising per-token latency — the continuous
    batching trade the subsystem exists to model.
    """

    prefill_tok_per_s: float = 24_000.0
    prefill_overhead_s: float = 0.015
    decode_base_s: float = 0.012
    decode_per_slot_s: float = 0.0015

    def prefill_s(self, prompt_tokens: int) -> float:
        return self.prefill_overhead_s + prompt_tokens / self.prefill_tok_per_s

    def decode_step_s(self, n_active: int) -> float:
        return self.decode_base_s + self.decode_per_slot_s * n_active


class BatchEngine:
    """Slotted continuous batcher for a single replica."""

    def __init__(self, n_slots: int, perf: ServingPerf):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.perf = perf
        self.slots: List[Optional[Request]] = [None] * n_slots
        # descending so .pop() hands out the lowest free slot (determinism)
        self._free = list(range(n_slots - 1, -1, -1))
        self.n_active = 0
        # accounting
        self.prefills = 0
        self.decode_steps = 0
        self.decode_slot_steps = 0   # sum of n_active over steps
        self.tokens_prefilled = 0
        self.tokens_generated = 0

    def has_free_slot(self) -> bool:
        return bool(self._free)

    # -- prefill --------------------------------------------------------------
    def begin_prefill(self, req: Request, t: float) -> float:
        """Admit ``req`` (it leaves the queue now); returns the prefill
        duration the caller should advance the clock by."""
        req.t_admitted = t
        self.prefills += 1
        self.tokens_prefilled += req.prompt_tokens
        return self.perf.prefill_s(req.prompt_tokens)

    def finish_prefill(self, req: Request, t: float) -> Optional[Request]:
        """Prefill produced the first token at ``t``. Single-token requests
        complete here (returned); the rest take a slot and decode."""
        req.t_first_token = t
        req.generated = 1
        self.tokens_generated += 1
        if req.gen_tokens <= 1:
            req.t_done = t
            return req
        slot = self._free.pop()
        self.slots[slot] = req
        self.n_active += 1
        return None

    # -- decode ---------------------------------------------------------------
    def decode_step_s(self) -> float:
        return self.perf.decode_step_s(self.n_active)

    def advance_decode(self, t: float) -> List[Request]:
        """One decode step ending at ``t``: every active slot gains a token;
        requests that hit their generation budget free their slot. Returns
        the completions, in slot order (deterministic)."""
        self.decode_steps += 1
        self.decode_slot_steps += self.n_active
        done: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated += 1
            self.tokens_generated += 1
            if req.generated >= req.gen_tokens:
                req.t_done = t
                self.slots[i] = None
                self._free.append(i)
                self.n_active -= 1
                done.append(req)
        if done:
            self._free.sort(reverse=True)
        return done

    # -- failure domain (chaos engine) ----------------------------------------
    def abort_all(self) -> List[Request]:
        """Evacuate every active slot (replica killed by a node loss):
        returns the aborted requests in slot order and resets the batch.
        Token accounting of work already done is kept — it was really
        computed, then lost with the replica."""
        aborted = [req for req in self.slots if req is not None]
        self.slots = [None] * self.n_slots
        self._free = list(range(self.n_slots - 1, -1, -1))
        self.n_active = 0
        return aborted

    # -- introspection --------------------------------------------------------
    @property
    def mean_occupancy(self) -> float:
        """Mean active slots per decode step (batch efficiency)."""
        if self.decode_steps == 0:
            return 0.0
        return self.decode_slot_steps / self.decode_steps
