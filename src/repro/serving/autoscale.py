"""Alert-driven autoscaling: incidents in, lease attaches/drains out.

The PR 7 ``AlertEngine`` was built so consumers would react to its
PENDING -> FIRING -> RESOLVED lifecycle instead of re-deriving thresholds;
this module is the first such consumer. A control loop on the virtual clock
polls one queue-delay burn-rate rule:

* **FIRING** and under ``max_replicas`` and past the up-cooldown: scale up
  one replica — a warm pool lease attach, so capacity arrives in a
  cold-start, not a re-deploy.
* **not firing** and over ``min_replicas``: drain at most one replica per
  tick, and only one that has been idle past ``idle_ttl_s`` — the
  hysteresis pair (cooldown up, TTL + one-per-tick down) that keeps a
  flapping alert from thrashing the fleet.

The alert engine itself stays passive: each control tick samples the hub
and calls ``alerts.evaluate`` on the virtual clock — the same read-only
evaluation the recorder metronome drives, just on the control cadence, so
a campaign replays bit-identically.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..obs.trace import NULL_RECORDER

#: ``AlertEngine.state()`` value this scaler keys on. A string literal —
#: serving is a hot package and may not import ``repro.obs.alerts`` at
#: module level (see tools/check_obs_imports.py).
_FIRING = "firing"


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    rule: str                        # alert rule name to watch
    min_replicas: int = 1
    max_replicas: int = 4
    control_every_s: float = 15.0
    scale_up_cooldown_s: float = 90.0
    idle_ttl_s: float = 120.0

    def __post_init__(self):
        if not (1 <= self.min_replicas <= self.max_replicas):
            raise ValueError(
                f"need 1 <= min <= max, got [{self.min_replicas}, {self.max_replicas}]"
            )
        if self.control_every_s <= 0:
            raise ValueError("control_every_s must be positive")


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One control-tick outcome, recorded for replay comparison."""

    t: float
    action: str          # "up" | "down" | "hold"
    replica: Optional[str]
    reason: str
    n_live: int          # fleet size after the decision


class Autoscaler:
    """SLO-aware fleet controller over a :class:`ReplicaSet`.

    ``alerts`` is duck-typed: anything with ``state(rule) -> str`` works
    (the hysteresis unit tests script one); a real ``AlertEngine`` (which
    also has ``hub`` and ``evaluate``) is additionally re-evaluated each
    tick so incident lifecycle keeps pace with the control loop.
    """

    def __init__(self, alerts, cfg: AutoscalerConfig, *, recorder=NULL_RECORDER):
        self.alerts = alerts
        self.cfg = cfg
        self.recorder = recorder
        self.decisions: List[ScaleDecision] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self.denied_ups = 0
        self._last_up = float("-inf")
        self._rset = None
        self._engine = None
        self._stop_when = None

    # -- wiring ---------------------------------------------------------------
    def bind(self, rset, engine, *, stop_when=None) -> "Autoscaler":
        """Attach the fleet and clock; ``stop_when()`` (optional) ends the
        control loop — without it the loop would keep the heap alive
        forever and the campaign could never drain."""
        self._rset = rset
        self._engine = engine
        self._stop_when = stop_when
        return self

    def start(self, t0: float) -> None:
        self._engine.at(t0, self._control)

    # -- control loop ---------------------------------------------------------
    def _control(self) -> None:
        now = self._engine.now
        self._refresh(now)
        self.decide(now)
        if self._stop_when is not None and self._stop_when():
            return
        self._engine.after(self.cfg.control_every_s, self._control)

    def _refresh(self, now: float) -> None:
        """Bring the alert engine up to date on the control cadence: sample
        the hub's probes, then run the (read-only) rule evaluation. Scripted
        fakes without ``hub``/``evaluate`` are simply polled as-is."""
        hub = getattr(self.alerts, "hub", None)
        evaluate = getattr(self.alerts, "evaluate", None)
        if hub is not None:
            hub.sample(now)
        if evaluate is not None:
            trace = self.recorder if self.recorder.enabled else None
            evaluate(now, trace)

    def decide(self, now: float) -> ScaleDecision:
        """One pure control decision against the current alert state —
        factored out so hysteresis is unit-testable without an engine."""
        cfg = self.cfg
        rset = self._rset
        if rset.n_live < cfg.min_replicas:
            # involuntary scale-down (node loss killed replicas): restoring
            # the floor is not a load decision, so it bypasses the
            # up-cooldown — but still competes for cluster capacity
            r = rset.scale_up(now, reason="floor-restore")
            if r is not None:
                self._last_up = now
                self.scale_ups += 1
                d = ScaleDecision(now, "up", r.name, "floor-restore", rset.n_live)
            else:
                self.denied_ups += 1
                d = ScaleDecision(now, "hold", None,
                                  "floor-restore denied: cluster busy", rset.n_live)
            self.decisions.append(d)
            rec = self.recorder
            if rec.enabled and d.action != "hold":
                rec.events.append((
                    "autoscale", now, d.action,
                    {"replica": d.replica, "reason": d.reason, "n_live": d.n_live},
                ))
            return d
        firing = self.alerts.state(cfg.rule) == _FIRING
        if firing:
            if (
                rset.n_live < cfg.max_replicas
                and now - self._last_up >= cfg.scale_up_cooldown_s
            ):
                r = rset.scale_up(now, reason=f"alert {cfg.rule} firing")
                if r is not None:
                    self._last_up = now
                    self.scale_ups += 1
                    d = ScaleDecision(now, "up", r.name,
                                      f"alert {cfg.rule} firing", rset.n_live)
                else:
                    self.denied_ups += 1
                    d = ScaleDecision(now, "hold", None,
                                      "scale-up denied: cluster busy", rset.n_live)
            else:
                why = ("at max_replicas" if rset.n_live >= cfg.max_replicas
                       else "up-cooldown")
                d = ScaleDecision(now, "hold", None, why, rset.n_live)
        else:
            victims = (
                rset.idle_replicas(now, cfg.idle_ttl_s)
                if rset.n_live > cfg.min_replicas else []
            )
            if victims:
                victim = victims[0]
                rset.scale_down(victim, now, reason="alert resolved + idle TTL")
                self.scale_downs += 1
                d = ScaleDecision(now, "down", victim.name,
                                  "alert resolved + idle TTL", rset.n_live)
            else:
                d = ScaleDecision(now, "hold", None, "steady", rset.n_live)
        self.decisions.append(d)
        rec = self.recorder
        if rec.enabled and d.action != "hold":
            rec.events.append((
                "autoscale", now, d.action,
                {"replica": d.replica, "reason": d.reason, "n_live": d.n_live},
            ))
        return d
