"""Pool-backed model replicas: stage weights once, lease per replica.

The paper's allocatable-storage claim applied to serving: model weights are
a *dataset*, so a fleet stages them **once** into a PERSISTENT pool and
every replica attaches a POOLED lease over the same
``StorageSpec -> open_session()`` path jobs use. Cold-start is then lease
attach plus weight page-in priced by the calibrated perfmodel — not a
per-replica deploy + re-stage — which is exactly what makes alert-driven
scale-up cheap enough to chase a traffic burst.

Lifecycle: a replica is STARTING while its lease attaches and weights page
in, ACTIVE while it serves, DRAINING once the autoscaler marks it down (it
finishes in-flight decodes, admits nothing), and STOPPED when its lease is
released. The pool — and the resident weights — outlive every replica.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

from ..chaos.retry import drive_retries
from ..core.staging import modeled_stage_time
from ..obs.trace import NULL_RECORDER
from ..pool.catalog import DatasetRef
from ..provision.spec import LifetimeClass, StorageSpec
from .batching import BatchEngine, ServingPerf


class ReplicaState(enum.Enum):
    STARTING = "starting"
    ACTIVE = "active"
    DRAINING = "draining"
    STOPPED = "stopped"


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """What a fleet serves: weight footprint plus per-replica shape."""

    name: str
    weight_bytes: float
    n_slots: int = 8
    perf: ServingPerf = ServingPerf()

    def __post_init__(self):
        if self.weight_bytes <= 0:
            raise ValueError(f"weight_bytes must be positive, got {self.weight_bytes}")


class Replica:
    """One serving instance: a pool lease, a batch engine, a step loop.

    The step loop is the replica's whole scheduler: while awake it prefers
    admitting a prefill (bounds TTFT), otherwise runs a decode step, and
    goes idle when it has neither. ``source`` is the campaign, duck-typed:
    ``pull() -> Request | None``, ``request_done(req)``.
    """

    def __init__(self, rid: int, name: str, *, session, batch: BatchEngine,
                 engine, rset: "ReplicaSet", source):
        self.rid = rid
        self.name = name
        self.session = session
        self.batch = batch
        self.engine = engine
        self.rset = rset
        self.source = source
        self.state = ReplicaState.STARTING
        self.started_at: float = 0.0
        self.active_at: Optional[float] = None
        self.stopped_at: Optional[float] = None
        self.cold_start_s: float = 0.0
        self.idle_since: Optional[float] = None
        self._busy = False
        #: request whose prefill is in flight (evacuated on a node kill)
        self._inflight = None

    # -- step loop ------------------------------------------------------------
    def wake(self) -> None:
        """Nudge an idle replica (new arrival, activation). No-op while a
        phase is in flight — re-entrancy is what the ``_busy`` latch
        prevents, so a burst of same-instant arrivals wakes each idle
        replica exactly once."""
        if self._busy or self.state not in (ReplicaState.ACTIVE, ReplicaState.DRAINING):
            return
        self._busy = True
        self._step()

    def _step(self) -> None:
        now = self.engine.now
        batch = self.batch
        if self.state is ReplicaState.ACTIVE and batch.has_free_slot():
            req = self.source.pull()
            if req is not None:
                self.idle_since = None
                req.replica = self.name
                self._inflight = req
                dt = batch.begin_prefill(req, now)
                self.engine.after(dt, lambda: self._prefill_done(req))
                return
        if batch.n_active:
            self.idle_since = None
            self.engine.after(batch.decode_step_s(), self._decode_done)
            return
        # nothing to prefill, nothing decoding: park until woken
        self._busy = False
        self.idle_since = now
        if self.state is ReplicaState.DRAINING:
            self.rset._finish_drain(self, now)

    def _prefill_done(self, req) -> None:
        if self.state is ReplicaState.STOPPED:
            return          # killed mid-prefill; the request was requeued
        self._inflight = None
        done = self.batch.finish_prefill(req, self.engine.now)
        if done is not None:
            self.source.request_done(done)
        self._step()

    def _decode_done(self) -> None:
        if self.state is ReplicaState.STOPPED:
            return          # killed mid-decode; active slots were requeued
        for req in self.batch.advance_decode(self.engine.now):
            self.source.request_done(req)
        self._step()


class ReplicaSet:
    """The fleet: one PERSISTENT weight pool, N leased replicas.

    ``listener`` (optional, duck-typed) hears ``replica_active(r)`` and
    ``replica_stopped(r)`` — the campaign uses it to kick queued work onto
    a freshly warm replica.
    """

    def __init__(
        self,
        service,
        engine,
        model: ModelProfile,
        *,
        pool_nodes: int = 2,
        n_compute_per_replica: int = 1,
        scratch_bytes: float = 0.0,
        managers: tuple = ("ephemeralfs",),
        name: str = "serving",
        recorder=NULL_RECORDER,
        source=None,
        listener=None,
    ):
        self.service = service
        self.engine = engine
        self.model = model
        self.pool_nodes = pool_nodes
        self.n_compute = n_compute_per_replica
        self.scratch_bytes = scratch_bytes
        self.managers = tuple(managers)
        self.name = name
        self.recorder = recorder
        self.source = source
        self.listener = listener
        self.weights = DatasetRef(f"weights/{model.name}", model.weight_bytes)
        self.pool_session = None
        self.weights_ready_at: Optional[float] = None
        self.weight_stage_s: float = 0.0
        self.replicas: List[Replica] = []
        #: ``(t, "up" | "down" | "stopped" | "up-denied", replica_name, reason)``
        self.scale_events: list = []
        self._n_live = 0
        self._last_t = 0.0
        self.replica_seconds = 0.0
        self.peak_replicas = 0

    # -- weight staging (exactly once) ----------------------------------------
    def stage_weights(self, now: float) -> float:
        """Create the PERSISTENT pool and stage the weights into it via a
        short-lived loader lease; returns the virtual time the weights are
        RESIDENT. Every later replica attach is a pure catalog hit — the
        trace's ``lease_attached`` events carry the proof (one miss total,
        from the loader)."""
        pool_spec = StorageSpec(
            f"{self.name}-pool",
            nodes=self.pool_nodes,
            lifetime=LifetimeClass.PERSISTENT,
            managers=self.managers,
        )
        self.pool_session = self.service.open_session(pool_spec, now=now)
        t = now + self.pool_session.provision_time_s
        loader = self.service.open_session(
            StorageSpec(
                f"{self.name}-weights",
                lifetime=LifetimeClass.POOLED,
                datasets=(self.weights,),
                managers=self.managers,
            ),
            now=t,
        )
        t += loader.provision_time_s + loader.stage_in_time_s
        loader.mark_staged(t)
        loader.release(t)
        self.weights_ready_at = t
        self.weight_stage_s = t - now
        rec = self.recorder
        if rec.enabled:
            rec.events.append((
                "weights_staged", t, self.model.name,
                {"bytes": self.model.weight_bytes, "stage_s": self.weight_stage_s,
                 "pool": pool_spec.name},
            ))
        return t

    # -- scaling --------------------------------------------------------------
    def scale_up(self, now: float, reason: str = "") -> Optional[Replica]:
        """Attach a lease and start a replica; ACTIVE after the cold-start
        (attach + perfmodel-priced weight page-in). ``None`` when the
        cluster can't grant the lease or compute nodes right now."""
        rid = len(self.replicas)
        spec = StorageSpec(
            f"{self.name}-r{rid:02d}",
            lifetime=LifetimeClass.POOLED,
            datasets=(self.weights,),
            stage_out_bytes=self.scratch_bytes,
            managers=self.managers,
        )
        session = self.service.try_open_session(
            spec, n_compute=self.n_compute, now=now
        )
        if session is None:
            self.scale_events.append((now, "up-denied", f"{self.name}-r{rid:02d}", reason))
            return None
        # page-in: replicas read the resident weights out of the pool into
        # device memory; an evicted dataset also re-pays its stage-in
        page_in_s = modeled_stage_time(
            self.model.weight_bytes, session.fs_model, None, spec.n_streams
        )
        cold = session.provision_time_s + session.stage_in_time_s + page_in_s
        r = Replica(
            rid, f"{self.name}-r{rid:02d}",
            session=session,
            batch=BatchEngine(self.model.n_slots, self.model.perf),
            engine=self.engine, rset=self, source=self.source,
        )
        r.started_at = now
        r.cold_start_s = cold
        self.replicas.append(r)
        self._account(now)
        self._n_live += 1
        self.peak_replicas = max(self.peak_replicas, self._n_live)
        self.scale_events.append((now, "up", r.name, reason))
        rec = self.recorder
        if rec.enabled:
            rec.events.append((
                "replica", now, r.name,
                {"state": "starting", "cold_start_s": cold,
                 "page_in_s": page_in_s, "restage_s": session.stage_in_time_s,
                 "reason": reason},
            ))
        self.engine.at(now + cold, lambda: self._activate(r))
        return r

    def _activate(self, r: Replica) -> None:
        if r.state is not ReplicaState.STARTING:
            return
        now = self.engine.now
        r.state = ReplicaState.ACTIVE
        r.active_at = now
        r.idle_since = now
        # publish (or re-publish, after an eviction re-stage) residency and
        # refresh the pool's LRU clock for the weights
        r.session.mark_staged(now)
        rec = self.recorder
        if rec.enabled:
            rec.events.append(("replica", now, r.name, {"state": "active"}))
        if self.listener is not None:
            self.listener.replica_active(r)

    def scale_down(self, r: Replica, now: float, reason: str = "") -> None:
        """Begin draining ``r``: no new admissions; the lease releases when
        its last decode finishes. The pool keeps the weights resident."""
        if r.state is not ReplicaState.ACTIVE:
            return
        r.state = ReplicaState.DRAINING
        self.scale_events.append((now, "down", r.name, reason))
        rec = self.recorder
        if rec.enabled:
            rec.events.append((
                "replica", now, r.name, {"state": "draining", "reason": reason}
            ))
        if not r._busy:
            self._finish_drain(r, now)

    def _finish_drain(self, r: Replica, now: float) -> None:
        if r.state is not ReplicaState.DRAINING:
            return
        r.state = ReplicaState.STOPPED
        r.stopped_at = now
        r._busy = False
        self._account(now)
        self._n_live -= 1
        r.session.release(now)
        self.scale_events.append((now, "stopped", r.name, ""))
        rec = self.recorder
        if rec.enabled:
            rec.events.append(("replica", now, r.name, {"state": "stopped"}))
        if self.listener is not None:
            self.listener.replica_stopped(r)

    # -- failure domain (chaos engine) ----------------------------------------
    def kill(self, r: Replica, now: float, reason: str = "node-loss") -> list:
        """Hard-stop ``r`` (its storage node died): every in-flight request
        — the prefill in flight and every active decode slot — aborts back
        to the source queue, the lease releases, and the autoscaler's floor
        restores the fleet on its next control tick. Returns the aborted
        requests (already requeued when a source is attached)."""
        if r.state is ReplicaState.STOPPED:
            return []
        r.state = ReplicaState.STOPPED
        r.stopped_at = now
        r._busy = False
        aborted = []
        if r._inflight is not None:
            aborted.append(r._inflight)
            r._inflight = None
        aborted.extend(r.batch.abort_all())
        self._account(now)
        self._n_live -= 1
        r.session.release(now)
        self.scale_events.append((now, "killed", r.name, reason))
        rec = self.recorder
        if rec.enabled:
            rec.events.append((
                "replica", now, r.name,
                {"state": "killed", "aborted": len(aborted), "reason": reason},
            ))
        if self.source is not None:
            # reversed: the source pushes each to the queue *front*, so the
            # earliest-admitted aborted request re-admits first
            for req in reversed(aborted):
                self.source.requeue(req)
        if self.listener is not None:
            self.listener.replica_stopped(r)
        return aborted

    def on_node_down(self, node_id: str, now: Optional[float] = None,
                     *, retry=None) -> List[Replica]:
        """Absorb a storage-node loss across the fleet.

        Replicas leasing from an affected pool (or whose own session spans
        the node) are killed — leases release first, unpinning the weights
        — then each affected pool takes the loss (residency invalidated,
        capacity shrunk) and, when a :class:`~repro.chaos.RetryPolicy` is
        passed, self-heals by backfilling from free nodes on its cadence.
        The next scale-up re-stages the weights through the ordinary miss
        path: degraded fleets never serve stale residency."""
        now = self.engine.now if now is None else now
        pm = self.service.pool_manager
        pools = pm.affected_pools(node_id) if pm is not None else ()
        pool_ids = {p.pool_id for p in pools}
        victims = []
        for r in self.replicas:
            if r.state is ReplicaState.STOPPED:
                continue
            lease = r.session.lease
            if (lease is not None and lease.pool_id in pool_ids) or any(
                n.node_id == node_id for n in r.session.storage_nodes
            ):
                victims.append(r)
        for r in victims:
            self.kill(r, now, reason=f"node-loss:{node_id}")
        for pool in pools:
            pm.on_node_down(pool, node_id, now)
            if retry is not None:
                drive_retries(
                    self.engine,
                    retry,
                    f"pool{pool.pool_id}:{node_id}",
                    lambda p=pool: pm.backfill(p, self.engine.now),
                )
        return victims

    # -- accounting / views ---------------------------------------------------
    def _account(self, now: float) -> None:
        """Advance the replica-seconds integral to ``now`` (call before any
        ``_n_live`` change, and once at campaign end)."""
        if now > self._last_t:
            self.replica_seconds += self._n_live * (now - self._last_t)
            self._last_t = now

    def finalize(self, now: float) -> None:
        self._account(now)

    @property
    def n_live(self) -> int:
        """Replicas currently holding a lease (STARTING/ACTIVE/DRAINING)."""
        return self._n_live

    @property
    def live(self) -> List[Replica]:
        return [r for r in self.replicas if r.state is not ReplicaState.STOPPED]

    @property
    def active(self) -> List[Replica]:
        return [r for r in self.replicas if r.state is ReplicaState.ACTIVE]

    def idle_replicas(self, now: float, ttl_s: float) -> List[Replica]:
        """ACTIVE replicas idle for at least ``ttl_s``, lowest rid first —
        the deterministic scale-down victim ordering."""
        return [
            r for r in self.replicas
            if r.state is ReplicaState.ACTIVE
            and r.idle_since is not None
            and now - r.idle_since >= ttl_s
        ]

    def wake_one(self) -> None:
        """Wake the lowest-rid idle ACTIVE replica (one arrival, one wake)."""
        for r in self.replicas:
            if r.state is ReplicaState.ACTIVE and not r._busy:
                r.wake()
                return
