"""The serving campaign driver: requests + fleet + autoscaler on one clock.

Rides the orchestrator's :class:`SimEngine` rather than hand-rolling an
event loop — arrivals, prefill/decode steps, replica activations and the
autoscaler's control ticks are all heap events on the same virtual clock,
so a campaign with tracing, alerting and scaling attached replays
bit-identically for a fixed seed.

Wiring order inside :meth:`ServingCampaign.run`:

1. stage weights once into the PERSISTENT pool (``ReplicaSet.stage_weights``),
2. spin up the initial fleet the moment the weights are RESIDENT,
3. feed arrivals into a FIFO queue; idle replicas are woken per arrival,
   busy replicas pull at their next step boundary,
4. (optional) start the autoscaler's control loop,
5. drain the heap and report.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Sequence

from ..obs.trace import NULL_RECORDER
from ..orchestrator.engine import SimEngine
from ..provision.service import ProvisioningService
from .replica import ModelProfile, ReplicaSet
from .workload import Request

#: histogram bounds tuned to serving latencies (the hub's defaults start
#: at 100 ms — too coarse for TPOT)
TTFT_BOUNDS = (0.1, 0.25, 0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0, 180.0, 600.0)
TPOT_BOUNDS = (0.005, 0.01, 0.015, 0.02, 0.03, 0.05, 0.1, 0.25, 1.0)


def _quantile(sorted_vals: Sequence[float], q: float) -> float:
    """Exact linear-interpolation quantile over a pre-sorted sample."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


@dataclasses.dataclass(frozen=True)
class ServingReport:
    """End-of-campaign rollup; percentiles are exact (per-request), not
    histogram-interpolated — the bench gates compare these."""

    n_requests: int
    n_completed: int
    weights_ready_at: float
    makespan_s: float
    ttft_p50_s: float
    ttft_p95_s: float
    ttft_p99_s: float
    tpot_p50_s: float
    tpot_p99_s: float
    queue_delay_p99_s: float
    e2e_p99_s: float
    tokens_generated: int
    tokens_prefilled: int
    tokens_per_s: float
    mean_occupancy: float
    replica_seconds: float
    peak_replicas: int
    n_replicas_final: int
    scale_ups: int
    scale_downs: int


def format_serving_report(r: ServingReport) -> str:
    lines = [
        f"requests      : {r.n_completed}/{r.n_requests} completed, "
        f"makespan {r.makespan_s:,.0f} s (weights ready at {r.weights_ready_at:,.0f} s)",
        f"TTFT          : p50 {r.ttft_p50_s:.2f} s | p95 {r.ttft_p95_s:.2f} s | "
        f"p99 {r.ttft_p99_s:.2f} s",
        f"TPOT          : p50 {r.tpot_p50_s * 1e3:.1f} ms | p99 {r.tpot_p99_s * 1e3:.1f} ms",
        f"queue delay   : p99 {r.queue_delay_p99_s:.2f} s   e2e p99 {r.e2e_p99_s:.2f} s",
        f"tokens        : {r.tokens_generated:,} generated "
        f"({r.tokens_per_s:,.0f} tok/s sustained), "
        f"{r.tokens_prefilled:,} prefilled, "
        f"mean batch occupancy {r.mean_occupancy:.2f}",
        f"fleet         : peak {r.peak_replicas}, final {r.n_replicas_final}, "
        f"{r.scale_ups} up / {r.scale_downs} down, "
        f"{r.replica_seconds:,.0f} replica-seconds",
    ]
    return "\n".join(lines)


class ServingCampaign:
    """One serving run: a request trace against a pool-backed fleet.

    Implements the replica ``source`` protocol (``pull`` /
    ``request_done``) and the :class:`ReplicaSet` listener protocol
    (``replica_active`` / ``replica_stopped``).
    """

    def __init__(
        self,
        cluster,
        model: ModelProfile,
        requests: Sequence[Request],
        *,
        initial_replicas: int = 1,
        autoscaler=None,
        recorder=NULL_RECORDER,
        pool_nodes: int = 2,
        n_compute_per_replica: int = 1,
        scratch_bytes: float = 0.0,
        sample_every: int = 64,
    ):
        if initial_replicas < 1:
            raise ValueError("initial_replicas must be >= 1")
        self.engine = SimEngine()
        # serving campaigns run far fewer heap events per virtual second
        # than a 50k-job batch campaign; tighten the metronome stride so the
        # alert engine sees bursts while they are live
        self.engine.SAMPLE_EVERY = sample_every
        self.service = ProvisioningService(cluster, clock=lambda: self.engine.now)
        self.recorder = recorder
        if recorder.enabled:
            recorder.bind_engine(self.engine, self.service)
        self.model = model
        self.requests = list(requests)
        self.initial_replicas = initial_replicas
        self.autoscaler = autoscaler
        self.rset = ReplicaSet(
            self.service, self.engine, model,
            pool_nodes=pool_nodes,
            n_compute_per_replica=n_compute_per_replica,
            scratch_bytes=scratch_bytes,
            recorder=recorder,
            source=self, listener=self,
        )
        self._queue: deque = deque()
        self.completed: List[Request] = []
        #: ``(rid, t_done)`` in completion-event order — the determinism
        #: regression compares this list across replays
        self.completion_order: list = []
        self._hub = recorder.metrics if recorder.enabled else None
        self._hist_ttft = None
        self._hist_tpot = None
        if self._hub is not None:
            self._register_metrics()

    # -- metrics --------------------------------------------------------------
    def _register_metrics(self) -> None:
        hub = self._hub
        engine = self.engine
        queue = self._queue
        rset = self.rset
        hub.add_probe("serving/queue_depth", lambda: len(queue))
        hub.add_probe(
            "serving/queue_delay_s",
            lambda: engine.now - queue[0].t_submit if queue else 0.0,
        )
        hub.add_probe("serving/n_replicas", lambda: rset.n_live)
        hub.add_probe(
            "serving/active_slots",
            lambda: sum(r.batch.n_active for r in rset.live),
        )
        self._hist_ttft = hub.histogram("serving/ttft_s", bounds=TTFT_BOUNDS)
        self._hist_tpot = hub.histogram("serving/tpot_s", bounds=TPOT_BOUNDS)

    # -- replica source protocol ----------------------------------------------
    def pull(self) -> Optional[Request]:
        if not self._queue:
            return None
        return self._queue.popleft()

    def requeue(self, req: Request) -> None:
        """A killed replica hands its in-flight requests back (chaos path):
        reset the measured lifecycle and put the request at the *front* of
        the queue — it was admitted first, it re-admits first."""
        req.replica = None
        req.t_admitted = None
        req.t_first_token = None
        req.t_done = None
        req.generated = 0
        self._queue.appendleft(req)
        self.rset.wake_one()

    def request_done(self, req: Request) -> None:
        self.completed.append(req)
        self.completion_order.append((req.rid, req.t_done))
        hub = self._hub
        if hub is not None:
            hub.counter("serving/requests_completed").inc()
            self._hist_ttft.observe(req.ttft_s)
            if req.tpot_s is not None:
                self._hist_tpot.observe(req.tpot_s)

    # -- replica-set listener protocol ----------------------------------------
    def replica_active(self, r) -> None:
        if self._queue:
            r.wake()

    def replica_stopped(self, r) -> None: ...

    # -- arrivals -------------------------------------------------------------
    def _arrive(self, req: Request) -> None:
        self._queue.append(req)
        if self._hub is not None:
            self._hub.counter("serving/requests_submitted").inc()
        self.rset.wake_one()

    # -- run ------------------------------------------------------------------
    def _quiescent(self) -> bool:
        floor = (
            self.autoscaler.cfg.min_replicas
            if self.autoscaler is not None else self.initial_replicas
        )
        return (
            len(self.completed) >= len(self.requests)
            and self.rset.n_live <= floor
        )

    def run(self, *, max_events: Optional[int] = None) -> ServingReport:
        rset = self.rset
        t_ready = rset.stage_weights(0.0)

        def bootstrap():
            now = self.engine.now
            for _ in range(self.initial_replicas):
                rset.scale_up(now, reason="initial fleet")

        self.engine.at(t_ready, bootstrap)
        self.engine.at_many(
            (req.t_submit, (lambda r=req: self._arrive(r)))
            for req in self.requests
        )
        if self.autoscaler is not None:
            self.autoscaler.bind(rset, self.engine, stop_when=self._quiescent)
            self.autoscaler.start(t_ready + self.autoscaler.cfg.control_every_s)
        if max_events is None:
            # generous backstop: every request costs a handful of heap
            # events (arrival, prefill, its share of decode steps)
            max_events = 10_000 + 400 * len(self.requests)
        self.engine.run(max_events=max_events)
        rset.finalize(self.engine.now)
        return self.report()

    # -- reporting ------------------------------------------------------------
    def report(self) -> ServingReport:
        done = self.completed
        ttfts = sorted(r.ttft_s for r in done) if done else []
        tpots = sorted(r.tpot_s for r in done if r.tpot_s is not None)
        qdels = sorted(r.queue_delay_s for r in done) if done else []
        e2es = sorted(r.e2e_s for r in done) if done else []
        tokens_gen = sum(b.tokens_generated for b in self._batches())
        tokens_pre = sum(b.tokens_prefilled for b in self._batches())
        steps = sum(b.decode_steps for b in self._batches())
        slot_steps = sum(b.decode_slot_steps for b in self._batches())
        t_first = min(
            (r.active_at for r in self.rset.replicas if r.active_at is not None),
            default=0.0,
        )
        t_last = max((r.t_done for r in done), default=t_first)
        window = max(t_last - t_first, 1e-9)
        return ServingReport(
            n_requests=len(self.requests),
            n_completed=len(done),
            weights_ready_at=self.rset.weights_ready_at or 0.0,
            makespan_s=t_last,
            ttft_p50_s=_quantile(ttfts, 0.50),
            ttft_p95_s=_quantile(ttfts, 0.95),
            ttft_p99_s=_quantile(ttfts, 0.99),
            tpot_p50_s=_quantile(tpots, 0.50),
            tpot_p99_s=_quantile(tpots, 0.99),
            queue_delay_p99_s=_quantile(qdels, 0.99),
            e2e_p99_s=_quantile(e2es, 0.99),
            tokens_generated=tokens_gen,
            tokens_prefilled=tokens_pre,
            tokens_per_s=tokens_gen / window,
            mean_occupancy=(slot_steps / steps) if steps else 0.0,
            replica_seconds=self.rset.replica_seconds,
            peak_replicas=self.rset.peak_replicas,
            n_replicas_final=self.rset.n_live,
            scale_ups=sum(1 for e in self.rset.scale_events if e[1] == "up") -
            self.initial_replicas,
            scale_downs=sum(1 for e in self.rset.scale_events if e[1] == "down"),
        )

    def _batches(self):
        return [r.batch for r in self.rset.replicas]
