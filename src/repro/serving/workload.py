"""Request-level serving workloads: seeded traffic over the arrival laws.

The orchestrator's arrival module (``repro.orchestrator.arrivals``) supplies
*when* requests land — Poisson, diurnal, burst — and this module supplies
*what* lands: per-request prompt and generation token counts drawn from a
seeded lognormal, the standard heavy-tailed shape for LLM traffic. Every
draw comes from a private ``random.Random``, so a (arrivals, lengths) seed
pair replays a campaign bit-identically.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Optional, Sequence


@dataclasses.dataclass(slots=True)
class Request:
    """One inference request and its measured lifecycle.

    The submit time and token counts are the workload's inputs; the
    remaining timestamps are written by the batch engine as the request
    moves queue -> prefill -> decode -> done on the virtual clock.
    """

    rid: int
    t_submit: float
    prompt_tokens: int
    gen_tokens: int
    # measured by the serving stack
    replica: Optional[str] = None
    t_admitted: Optional[float] = None     # prefill start (leaves the queue)
    t_first_token: Optional[float] = None  # prefill end
    t_done: Optional[float] = None
    generated: int = 0

    @property
    def queue_delay_s(self) -> Optional[float]:
        if self.t_admitted is None:
            return None
        return self.t_admitted - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token: submit -> end of prefill."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def tpot_s(self) -> Optional[float]:
        """Time per output token over the decode phase (None until done,
        and for single-token requests, which never decode)."""
        if self.t_done is None or self.gen_tokens <= 1:
            return None
        return (self.t_done - self.t_first_token) / (self.gen_tokens - 1)

    @property
    def e2e_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """Clamped lognormal token-length distribution.

    ``mean`` is the target mean of the *unclamped* lognormal; ``sigma`` is
    the log-space spread (0 degenerates to the constant ``mean``).
    """

    mean: float
    sigma: float = 0.6
    lo: int = 1
    hi: int = 8192

    def __post_init__(self):
        if self.mean <= 0:
            raise ValueError(f"mean must be positive, got {self.mean}")
        if self.sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")
        if not (0 < self.lo <= self.hi):
            raise ValueError(f"need 0 < lo <= hi, got [{self.lo}, {self.hi}]")

    def sample(self, rng: random.Random) -> int:
        if self.sigma == 0:
            raw = self.mean
        else:
            # mu chosen so E[lognormal] == mean
            mu = math.log(self.mean) - 0.5 * self.sigma * self.sigma
            raw = rng.lognormvariate(mu, self.sigma)
        return max(self.lo, min(self.hi, round(raw)))


def synthesize_requests(
    times: Sequence[float],
    *,
    seed: int = 0,
    prompt: LengthDist = LengthDist(mean=512.0, hi=4096),
    gen: LengthDist = LengthDist(mean=96.0, hi=1024),
) -> list[Request]:
    """One :class:`Request` per arrival time, lengths drawn from ``seed``.

    Times must be non-decreasing (feed them straight from an arrival law or
    ``sorted(...)`` a merged trace first).
    """
    rng = random.Random(seed)
    out: list[Request] = []
    prev = float("-inf")
    for rid, t in enumerate(times):
        if t < prev:
            raise ValueError(
                f"arrival times must be non-decreasing: t[{rid}]={t} < {prev}"
            )
        prev = t
        out.append(
            Request(
                rid=rid,
                t_submit=float(t),
                prompt_tokens=prompt.sample(rng),
                gen_tokens=gen.sample(rng),
            )
        )
    return out
