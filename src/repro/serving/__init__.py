"""SLO-aware pool-backed model serving on the provisioning substrate.

The ROADMAP's first serving milestone: model weights are a dataset staged
**once** into a PERSISTENT pool; replicas are POOLED leases plus a
continuous-batching token loop; traffic follows seeded diurnal/burst
arrival laws; and an :class:`Autoscaler` grows and drains the fleet by
consuming the PR 7 ``AlertEngine``'s incident lifecycle. Everything runs
on the orchestrator's :class:`SimEngine` virtual clock and is traced
through the PR 6 recorder, so campaigns replay bit-identically.

Hot-path layering rule (enforced by ``tools/check_obs_imports.py``): these
modules may import only ``repro.obs.trace`` from the observability package
at module level.
"""

from .autoscale import Autoscaler, AutoscalerConfig, ScaleDecision
from .batching import BatchEngine, ServingPerf
from .campaign import (
    ServingCampaign,
    ServingReport,
    format_serving_report,
)
from .replica import ModelProfile, Replica, ReplicaSet, ReplicaState
from .workload import LengthDist, Request, synthesize_requests

__all__ = [
    "Autoscaler", "AutoscalerConfig", "ScaleDecision",
    "BatchEngine", "ServingPerf",
    "ServingCampaign", "ServingReport", "format_serving_report",
    "ModelProfile", "Replica", "ReplicaSet", "ReplicaState",
    "LengthDist", "Request", "synthesize_requests",
]
