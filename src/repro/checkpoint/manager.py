"""Checkpointing on dynamically provisioned storage — the paper's motivating
use-case (§III-B mentions the Burst-Buffer plugin exists for check-pointing)
built as a first-class subsystem.

Design informed by the paper's measurements:
  * **file-per-shard layout** (C3/C4: file-per-process reaches ~93% of raw
    disk bandwidth vs ~55% for a single shared file) — each pytree leaf
    (or leaf slab) is its own object;
  * **burst then drain**: save() lands on the provisioned EphemeralFS at
    burst-tier speed; drain_to() copies a committed checkpoint to the global
    FS in the background of training (the paper's stage-out);
  * **two-phase commit**: data files + manifest first, then a COMMIT marker;
    restore() only considers committed steps, so a mid-save crash is
    harmless (tested).
"""

from __future__ import annotations

import io
import json
import threading
from typing import Any, Optional

import jax
import numpy as np

from ..core.client import FSClient
from ..core.datamanager import DataManager, FSError
from ..core.staging import stage


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(
        self,
        burst: DataManager,
        root: str = "/ckpt",
        *,
        global_fs: Optional[DataManager] = None,
        global_root: str = "/persist/ckpt",
        keep: int = 3,
    ):
        self.burst = burst
        self.client = FSClient(burst, "ckpt")
        self.root = root.rstrip("/")
        self.global_fs = global_fs
        self.global_root = global_root.rstrip("/")
        self.keep = keep
        self._drains: list = []
        self.client.makedirs(self.root)

    # -- save -----------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return f"{self.root}/step-{step:08d}"

    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> dict:
        """Write a sharded checkpoint; returns manifest dict."""
        d = self._step_dir(step)
        self.client.makedirs(d)
        leaves = _flatten_with_paths(tree)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        total = 0
        for key, leaf in leaves:
            arr = np.asarray(leaf)
            fname = key.replace("/", ".") + ".npy"
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            data = buf.getvalue()
            self.client.write_file(f"{d}/{fname}", data)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "bytes": len(data)}
            )
            total += len(data)
        manifest["total_bytes"] = total
        self.client.write_file(f"{d}/manifest.json", json.dumps(manifest).encode())
        # two-phase commit marker
        self.client.write_file(f"{d}/COMMIT", b"ok")
        self._gc()
        return manifest

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            self._rm_tree(self._step_dir(s))

    def _rm_tree(self, d: str) -> None:
        try:
            names = self.client.readdir(d)
        except FSError:
            return
        for n in names:
            p = f"{d}/{n}"
            if self.client.stat(p).is_dir:
                self._rm_tree(p)
            else:
                self.client.unlink(p)
        self.client.rmdir(d)

    # -- restore ----------------------------------------------------------
    def steps(self) -> list[int]:
        """Committed steps, ascending."""
        out = []
        for name in self.client.readdir(self.root):
            if not name.startswith("step-"):
                continue
            d = f"{self.root}/{name}"
            if self.client.exists(f"{d}/COMMIT"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def restore(self, tree_like: Any, step: Optional[int] = None) -> tuple[Any, int]:
        steps = self.steps()
        if not steps:
            raise FSError("no committed checkpoints")
        step = steps[-1] if step is None else step
        if step not in steps:
            raise FSError(f"step {step} not committed (have {steps})")
        d = self._step_dir(step)
        manifest = json.loads(self.client.read_file(f"{d}/manifest.json"))
        by_key = {m["key"]: m for m in manifest["leaves"]}
        leaves = _flatten_with_paths(tree_like)
        out = []
        for key, like in leaves:
            m = by_key[key]
            raw = self.client.read_file(f"{d}/{m['file']}")
            arr = np.load(io.BytesIO(raw), allow_pickle=False)
            out.append(jax.numpy.asarray(arr))
        restored = jax.tree.unflatten(jax.tree.structure(tree_like), out)
        return restored, step

    # -- drain (stage-out to the global FS) -------------------------------
    def drain_async(self, step: int) -> threading.Thread:
        """Start the drain off the training path; join() the returned thread
        (or call wait_drains) before tearing the burst tier down."""
        t = threading.Thread(target=self.drain_to_global, args=(step,),
                             name=f"ckpt-drain-{step}", daemon=True)
        self._drains.append(t)
        t.start()
        return t

    def wait_drains(self) -> None:
        for t in self._drains:
            t.join()
        self._drains.clear()

    def drain_to_global(self, step: int) -> dict:
        if self.global_fs is None:
            raise FSError("no global FS configured")
        d = self._step_dir(step)
        names = self.client.readdir(d)
        dst = f"{self.global_root}/step-{step:08d}"
        pairs = [(f"{d}/{n}", f"{dst}/{n}") for n in names if n != "COMMIT"]
        rep = stage(self.burst, self.global_fs, pairs, direction="out")
        FSClient(self.global_fs, "ckpt-drain").write_file(f"{dst}/COMMIT", b"ok")
        return {"files": rep.files, "bytes": rep.bytes,
                "modeled_time_s": rep.modeled_time_s}
