"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunked-parallel
for train/prefill, O(1)-state for decode) and sLSTM (scalar memory with
exponential gating and per-head recurrence, ``lax.scan`` over time).

Stabilization follows the paper: running log-scale ``m`` with
``h = num / max(|den|, exp(-m))`` for mLSTM and the max-trick for sLSTM's
exponential input gate.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense, dense_init, rmsnorm
from .mamba2 import _causal_conv


class MLSTMCache(NamedTuple):
    conv: jnp.ndarray      # (B, W-1, di)
    C: jnp.ndarray         # (B, H, dh, dh)
    n: jnp.ndarray         # (B, H, dh)
    m: jnp.ndarray         # (B, H)


class SLSTMCache(NamedTuple):
    c: jnp.ndarray         # (B, H, dh)
    n: jnp.ndarray
    m: jnp.ndarray
    h: jnp.ndarray


def _ff_dim(d: int) -> int:
    f = (4 * d + 2) // 3
    return ((f + 63) // 64) * 64


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    W = cfg.ssm_conv_width
    rs = jax.random.split(rng, 7)
    return {
        "in_proj": dense_init(rs[0], d, 2 * di, dtype=dtype),
        "conv_w": (jax.random.normal(rs[1], (W, di)) * (W ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype=dtype),
        "wq": dense_init(rs[2], di, di, dtype=dtype),
        "wk": dense_init(rs[3], di, di, dtype=dtype),
        "wv": dense_init(rs[4], di, di, dtype=dtype),
        "w_gates": dense_init(rs[5], di, 2 * H, bias=True, dtype=jnp.float32),
        "skip": jnp.ones((di,), dtype=dtype),
        "gnorm": {"scale": jnp.ones((di,), dtype=dtype)},
        "out_proj": dense_init(rs[6], di, d, dtype=dtype),
    }


def _mlstm_chunk(q, k, v, log_f, log_i, carry, eps=1e-6):
    """One chunk of the stabilized parallel mLSTM.

    q,k,v: (B,Q,H,dh) f32 (q pre-scaled); log_f/log_i: (B,Q,H) f32;
    carry: (C (B,H,dh,dh), n (B,H,dh), m (B,H)).
    """
    C_prev, n_prev, m_prev = carry
    B, Q, H, dh = q.shape
    F = jnp.cumsum(log_f, axis=1)                      # (B,Q,H)
    # W_ts = F_t - F_s + log_i_s  for s <= t
    Wmat = F[:, :, None, :] - F[:, None, :, :] + log_i[:, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), dtype=bool))[None, :, :, None]
    Wmat = jnp.where(tri, Wmat, -jnp.inf)              # (B,Q,Q,H) [t, s]
    G = F + m_prev[:, None, :]                         # (B,Q,H)
    m_loc = jnp.max(Wmat, axis=2)                      # (B,Q,H)
    m_t = jnp.maximum(m_loc, G)
    D = jnp.exp(Wmat - m_t[:, :, None, :])             # (B,Q,Q,H)
    qk = jnp.einsum("bqhd,bshd->bqsh", q, k)           # (B,Q,Q,H)
    A = D * qk
    num = jnp.einsum("bqsh,bshd->bqhd", A, v)
    num = num + jnp.exp(G - m_t)[..., None] * jnp.einsum(
        "bqhd,bhde->bqhe", q, C_prev
    )
    den = A.sum(axis=2)                                # (B,Q,H)
    den = den + jnp.exp(G - m_t) * jnp.einsum("bqhd,bhd->bqh", q, n_prev)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # carry update to chunk end
    Fq = F[:, -1, :]                                   # (B,H)
    w_end = Fq[:, None, :] - F + log_i                 # (B,Q,H)
    m_new = jnp.maximum(Fq + m_prev, jnp.max(w_end, axis=1))
    scale_old = jnp.exp(Fq + m_prev - m_new)
    wk_end = jnp.exp(w_end - m_new[:, None, :])
    C_new = scale_old[:, :, None, None] * C_prev + jnp.einsum(
        "bqh,bqhd,bqhe->bhde", wk_end, k, v
    )
    n_new = scale_old[:, :, None] * n_prev + jnp.einsum("bqh,bqhd->bhd", wk_end, k)
    return h, (C_new, n_new, m_new)


def mlstm_core(q, k, v, log_f, log_i, Q: int, carry=None):
    """q,k,v: (B,S,H,dh); gates (B,S,H). Returns (h (B,S,H,dh), carry)."""
    B, S, H, dh = q.shape
    f32 = jnp.float32
    q = q.astype(f32) * (dh ** -0.5)
    k = k.astype(f32)
    v = v.astype(f32)
    log_f = log_f.astype(f32)
    log_i = log_i.astype(f32)
    if carry is None:
        carry = (
            jnp.zeros((B, H, dh, dh), f32),
            jnp.zeros((B, H, dh), f32),
            jnp.full((B, H), -1e30, f32),
        )
    if S == 1:
        h, carry = _mlstm_chunk(q, k, v, log_f, log_i, carry)
        return h, carry
    S_orig = S
    if S % Q:
        # pad tail with identity steps: f=1, i=0 -> carry unchanged
        pad = Q - S % Q
        zpad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        zpad3 = ((0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(t, zpad4) for t in (q, k, v))
        log_f = jnp.pad(log_f, zpad3)
        log_i = jnp.pad(log_i, zpad3, constant_values=-1e30)
        S = S + pad
    nc = S // Q

    def body(c, inp):
        qc, kc, vc, fc, ic = inp
        h, c = _mlstm_chunk(qc, kc, vc, fc, ic, c)
        return c, h

    split = lambda t: t.reshape(B, nc, Q, *t.shape[2:]).transpose(1, 0, *range(2, t.ndim + 1))
    carry, hs = jax.lax.scan(body, carry, tuple(split(t) for t in (q, k, v, log_f, log_i)))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)[:, :S_orig]
    return h, carry


def mlstm_forward(p, x, cfg: ModelConfig, *, cache: MLSTMCache | None = None):
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    dh = di // H
    xz = dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)

    if cache is None:
        cx = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
        new_conv = None
        carry = None
    else:
        window = jnp.concatenate([cache.conv, x_in], axis=1)
        cx = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"])[:, None]
        new_conv = window[:, 1:, :]
        carry = (cache.C, cache.n, cache.m)

    from ..hints import constrain
    q = constrain(dense(p["wq"], cx).reshape(B, S, H, dh), "dp", None, "model", None)
    k = constrain(dense(p["wk"], cx).reshape(B, S, H, dh), "dp", None, "model", None)
    v = constrain(dense(p["wv"], x_in).reshape(B, S, H, dh), "dp", None, "model", None)
    gates = dense(p["w_gates"], x_in.astype(jnp.float32))
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)        # (B,S,H) each
    log_f = jax.nn.log_sigmoid(f_pre)
    h, carry = mlstm_core(q, k, v, log_f, i_pre, cfg.ssm_chunk, carry)

    h = h.reshape(B, S, di).astype(x.dtype)
    h = rmsnorm(p["gnorm"], h, cfg.norm_eps) + p["skip"] * cx
    h = h * jax.nn.silu(z)
    out = dense(p["out_proj"], h)
    new_cache = None
    if cache is not None:
        new_cache = MLSTMCache(conv=new_conv, C=carry[0], n=carry[1], m=carry[2])
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    dh = di // H
    ff = _ff_dim(d)
    rs = jax.random.split(rng, 4)
    return {
        "in_norm": {"scale": jnp.ones((d,), dtype=dtype)},
        "w_in": dense_init(rs[0], d, 4 * di, bias=True, dtype=dtype),
        "R": (jax.random.normal(rs[1], (4, H, dh, dh)) * (dh ** -0.5)).astype(dtype),
        "gnorm": {"scale": jnp.ones((di,), dtype=dtype)},
        "out_proj": dense_init(rs[2], di, d, dtype=dtype),
        "ffn": {
            "up": dense_init(jax.random.fold_in(rs[3], 0), d, 2 * ff, dtype=dtype),
            "down": dense_init(jax.random.fold_in(rs[3], 1), ff, d, dtype=dtype),
        },
        "ffn_norm": {"scale": jnp.ones((d,), dtype=dtype)},
    }


def slstm_cell(p, xg, state: SLSTMCache):
    """One recurrent step. xg: (B, 4, H, dh) pre-activations from the input."""
    c, n, m, h = state
    R = p["R"].astype(jnp.float32)
    rec = jnp.einsum("bhd,ghde->bghe", h, R)           # (B,4,H,dh)
    pre = xg.astype(jnp.float32) + rec
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    # scalar gates per head: mean over the head dim (keeps params dense)
    i_t = i_pre.mean(-1)                               # (B,H)
    f_t = jax.nn.log_sigmoid(f_pre.mean(-1))
    m_new = jnp.maximum(f_t + m, i_t)
    i_s = jnp.exp(i_t - m_new)[..., None]
    f_s = jnp.exp(f_t + m - m_new)[..., None]
    z_t = jnp.tanh(z_pre)
    o_t = jax.nn.sigmoid(o_pre)
    c_new = f_s * c + i_s * z_t
    n_new = f_s * n + i_s
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMCache(c_new, n_new, m_new, h_new)


def slstm_forward(p, x, cfg: ModelConfig, *, cache: SLSTMCache | None = None):
    """Full sLSTM block: pre-norm mixer + post FFN. Takes the RAW residual
    stream and returns the updated stream (it owns two residual adds)."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    dh = di // H
    xn = rmsnorm(p["in_norm"], x, cfg.norm_eps)
    xg = dense(p["w_in"], xn).reshape(B, S, 4, H, dh)
    state = cache if cache is not None else empty_slstm_state(cfg, B)

    if S == 1:
        state = slstm_cell(p, xg[:, 0], state)
        hs = state.h[:, None]
    else:
        def body(st, xt):
            st = slstm_cell(p, xt, st)
            return st, st.h

        state, hs = jax.lax.scan(body, state, xg.transpose(1, 0, 2, 3, 4))
        hs = hs.transpose(1, 0, 2, 3)                  # (B,S,H,dh)

    h = hs.reshape(B, S, di).astype(x.dtype)
    h = rmsnorm(p["gnorm"], h, cfg.norm_eps)
    x = x + dense(p["out_proj"], h)
    # post-block gated FFN (GeLU, ~4/3 factor)
    y = rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
    u, g = jnp.split(dense(p["ffn"]["up"], y), 2, axis=-1)
    x = x + dense(p["ffn"]["down"], jax.nn.gelu(u) * g)
    return x, (state if cache is not None else None)


def empty_slstm_state(cfg: ModelConfig, B: int) -> SLSTMCache:
    H = cfg.n_heads
    dh = cfg.ssm_expand * cfg.d_model // H
    f32 = jnp.float32
    return SLSTMCache(
        c=jnp.zeros((B, H, dh), f32),
        n=jnp.zeros((B, H, dh), f32),
        m=jnp.full((B, H), -1e30, f32),
        h=jnp.zeros((B, H, dh), f32),
    )


def empty_mlstm_cache(cfg: ModelConfig, B: int, dtype) -> MLSTMCache:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    dh = di // H
    W = cfg.ssm_conv_width
    f32 = jnp.float32
    return MLSTMCache(
        conv=jnp.zeros((B, W - 1, di), dtype=dtype),
        C=jnp.zeros((B, H, dh, dh), f32),
        n=jnp.zeros((B, H, dh), f32),
        m=jnp.full((B, H), -1e30, f32),
    )
