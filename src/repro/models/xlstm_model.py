"""xLSTM LM assembly: blocks of [1 sLSTM + (period-1) mLSTM], scanned over the
mLSTM stacks (sLSTM blocks are unrolled per block group)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from .common import Model, remat_wrap, stack_init, token_specs
from .layers import (
    cross_entropy_loss,
    dtype_of,
    embed,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)
from .xlstm import (
    MLSTMCache,
    SLSTMCache,
    empty_mlstm_cache,
    empty_slstm_state,
    mlstm_forward,
    mlstm_init,
    slstm_forward,
    slstm_init,
)


def _blocks(cfg: ModelConfig) -> tuple[int, int]:
    p = cfg.slstm_period
    nb = cfg.n_layers // p
    return nb, p - 1  # (n blocks, mLSTM layers per block)


def _m_layer_init(rng, cfg, dtype):
    return {
        "norm": rmsnorm_init(cfg.d_model, dtype),
        "mlstm": mlstm_init(rng, cfg, dtype=dtype),
    }


def _m_layer(lp, x, cfg, cache=None):
    h, new_cache = mlstm_forward(
        lp["mlstm"], rmsnorm(lp["norm"], x, cfg.norm_eps), cfg, cache=cache
    )
    return x + h, new_cache


def init(rng, cfg: ModelConfig):
    dtype = dtype_of(cfg)
    nb, nm = _blocks(cfg)
    r_emb, r_s, r_m, r_un = jax.random.split(rng, 4)
    m_fn = functools.partial(_m_layer_init, cfg=cfg, dtype=dtype)
    m_all = stack_init(r_m, nb * nm, m_fn)
    params = {
        "embed": embed_init(r_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "slstm": stack_init(r_s, nb, lambda r: slstm_init(r, cfg, dtype=dtype)),
        "mlstm": jax.tree.map(lambda a: a.reshape(nb, nm, *a.shape[1:]), m_all),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(r_un, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def _forward(params, cfg, x, *, caches=None, remat=None):
    """caches: None (train) or dict of stacked decode caches."""
    nb, nm = _blocks(cfg)
    m_layer = remat_wrap(functools.partial(_m_layer, cfg=cfg), remat)
    new_caches = {"s": [], "m": []} if caches is not None else None

    for b in range(nb):
        sp = jax.tree.map(lambda a: a[b], params["slstm"])
        mp = jax.tree.map(lambda a: a[b], params["mlstm"])
        if caches is None:
            x, _ = slstm_forward(sp, x, cfg)

            def inner(xc, lp):
                xc, _ = m_layer(lp, xc)
                return xc, None

            x, _ = jax.lax.scan(inner, x, mp)
        else:
            s_st = jax.tree.map(lambda a: a[b], caches["s"])
            m_st = jax.tree.map(lambda a: a[b], caches["m"])
            x, s_new = slstm_forward(sp, x, cfg, cache=SLSTMCache(*s_st))

            def inner(xc, inp):
                lp, conv, C, n, m = inp
                xc, st = _m_layer(lp, xc, cfg, cache=MLSTMCache(conv, C, n, m))
                return xc, st

            x, m_new = jax.lax.scan(inner, x, (mp,) + tuple(m_st))
            new_caches["s"].append(tuple(s_new))
            new_caches["m"].append(tuple(m_new))

    if new_caches is not None:
        # re-stacking per-block states drops sharding annotations and GSPMD
        # replicates the whole matrix memory at the output boundary (a 5.6 GB
        # all-gather per step, measured); pin the batch axis explicitly.
        from ..hints import constrain

        def restack(parts, batch_axis):
            out = []
            for t in zip(*parts):
                a = jnp.stack(t)
                spec = [None] * a.ndim
                spec[batch_axis] = "dp"
                out.append(constrain(a, *spec))
            return tuple(out)

        new_caches = {
            "s": restack(new_caches["s"], 1),
            "m": restack(new_caches["m"], 2),
        }
    return x, new_caches


def loss_fn(params, batch, cfg: ModelConfig, *, remat=None, use_kernels=False):
    x = embed(params["embed"], batch["tokens"])
    h, _ = _forward(params, cfg, x, remat=remat)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params.get("unembed", params["embed"]), h)
    ce = cross_entropy_loss(logits, batch["labels"])
    return ce, {"ce": ce, "aux": 0.0}


def prefill(params, batch, S_max: int, cfg: ModelConfig, *, use_kernels=False):
    """xLSTM has O(1) recurrent state; prefill = step the caches through the
    prompt. We run the chunked forward with state extraction: process the
    prompt as a single big chunk sequence via the decode cache path but with
    full-sequence kernels (states come from the chunk scans)."""
    x = embed(params["embed"], batch["tokens"])
    B, S = x.shape[:2]
    nb, nm = _blocks(cfg)
    caches = init_cache(cfg, B, S_max)
    new_caches = {"s": [], "m": []}

    for b in range(nb):
        sp = jax.tree.map(lambda a: a[b], params["slstm"])
        mp = jax.tree.map(lambda a: a[b], params["mlstm"])
        s_st = jax.tree.map(lambda a: a[b], caches["s"])
        x, s_new = slstm_forward(sp, x, cfg, cache=SLSTMCache(*s_st))

        # run mLSTM layers with explicit end-of-prompt state capture
        m_new = []
        for li in range(nm):
            lp = jax.tree.map(lambda a: a[li], mp)
            xn = rmsnorm(lp["norm"], x, cfg.norm_eps)
            from .layers import dense as _dense
            di = cfg.ssm_expand * cfg.d_model
            x_in_full = _dense(lp["mlstm"]["in_proj"], xn)
            x_in, _ = jnp.split(x_in_full, 2, axis=-1)
            conv_tail = x_in[:, -(cfg.ssm_conv_width - 1):, :]
            h, carry = _mlstm_with_carry(lp["mlstm"], xn, cfg)
            x = x + h
            m_new.append((conv_tail,) + carry)
        m_new = tuple(jnp.stack(t) for t in zip(*m_new))
        new_caches["s"].append(tuple(s_new))
        new_caches["m"].append(m_new)

    cache = {
        "s": tuple(jnp.stack(t) for t in zip(*new_caches["s"])),
        "m": tuple(jnp.stack(t) for t in zip(*new_caches["m"])),
        "pos": jnp.int32(S),
    }
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("unembed", params["embed"]), h[:, -1])
    return logits, cache


def _mlstm_with_carry(p, xn, cfg):
    """mlstm_forward but returning the end-of-sequence carry too."""
    from .layers import dense as _dense
    from .mamba2 import _causal_conv
    from .xlstm import mlstm_core
    B, S, d = xn.shape
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    dh = di // H
    xz = _dense(p["in_proj"], xn)
    x_in, z = jnp.split(xz, 2, axis=-1)
    from ..hints import constrain
    cx = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
    q = constrain(_dense(p["wq"], cx).reshape(B, S, H, dh), "dp", None, "model", None)
    k = constrain(_dense(p["wk"], cx).reshape(B, S, H, dh), "dp", None, "model", None)
    v = constrain(_dense(p["wv"], x_in).reshape(B, S, H, dh), "dp", None, "model", None)
    gates = _dense(p["w_gates"], x_in.astype(jnp.float32))
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)
    h, carry = mlstm_core(q, k, v, log_f, i_pre, cfg.ssm_chunk)
    h = h.reshape(B, S, di).astype(xn.dtype)
    h = rmsnorm(p["gnorm"], h, cfg.norm_eps) + p["skip"] * cx
    h = h * jax.nn.silu(z)
    return _dense(p["out_proj"], h), carry


def decode_step(params, cache, batch, cfg: ModelConfig, *, use_kernels=False):
    x = embed(params["embed"], batch["token"][:, None])
    caches = {"s": cache["s"], "m": cache["m"]}
    x, new_caches = _forward(params, cfg, x, caches=caches)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("unembed", params["embed"]), h[:, 0])
    new_caches["pos"] = cache["pos"] + 1
    return logits, new_caches


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    dtype = dtype_of(cfg)
    nb, nm = _blocks(cfg)
    s0 = empty_slstm_state(cfg, B)
    m0 = empty_mlstm_cache(cfg, B, dtype)

    def rep(a, *ns):
        return jnp.broadcast_to(a, ns + a.shape).copy()

    return {
        "s": tuple(rep(a, nb) for a in s0),
        "m": tuple(rep(a, nb, nm) for a in m0),
        "pos": jnp.int32(0),
    }


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return token_specs(shape)


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init, cfg=cfg),
        loss=functools.partial(loss_fn, cfg=cfg),
        prefill=functools.partial(prefill, cfg=cfg),
        decode_step=functools.partial(decode_step, cfg=cfg),
        init_cache=functools.partial(init_cache, cfg),
        input_specs=functools.partial(input_specs, cfg),
    )
