"""Decoder-only transformer LM assembly: dense, MoE, gemma3-style
local/global block pattern, and VLM (prefix patch embeddings).

Layer stacks are scanned (``lax.scan``) over stacked params for compile-time
O(1) in depth; gemma3 uses a nested scan over (blocks x [R local + 1 global]).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from .attention import KVCache, attention, attn_init
from .common import Model, remat_wrap, stack_init, token_specs
from .layers import (
    cross_entropy_loss,
    dense,
    dtype_of,
    embed,
    embed_init,
    norm as rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
    unembed,
)
from .moe import moe_ffn, moe_init

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# layer init / apply
# ---------------------------------------------------------------------------
def _layer_init(rng, cfg: ModelConfig, *, dtype):
    ra, rm = jax.random.split(rng)
    p = {
        "attn": attn_init(ra, cfg, dtype=dtype),
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe_init(rm, cfg, dtype=dtype)
    else:
        p["mlp"] = swiglu_init(rm, cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def _layer_apply(
    lp,
    x,
    cfg: ModelConfig,
    *,
    positions,
    theta: float,
    window: Optional[int],
    cache: Optional[KVCache] = None,
    cache_pos=None,
    cache_write_pos=None,
    kv_positions=None,
    use_kernels: bool = False,
):
    """Pre-norm block. Returns (x, new_kv, aux)."""
    h, kv = attention(
        lp["attn"],
        rmsnorm(lp["ln1"], x, cfg.norm_eps),
        cfg,
        positions=positions,
        theta=theta,
        window=window,
        cache=cache,
        cache_pos=cache_pos,
        cache_write_pos=cache_write_pos,
        kv_positions=kv_positions,
        use_kernels=use_kernels,
    )
    x = x + h
    y = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = moe_ffn(lp["moe"], y, cfg)
    else:
        m, aux = swiglu(lp["mlp"], y), 0.0
    return x + m, kv, aux


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init(rng, cfg: ModelConfig):
    dtype = dtype_of(cfg)
    r_emb, r_layers, r_un = jax.random.split(rng, 3)
    params = {
        "embed": embed_init(r_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(r_un, cfg.padded_vocab, cfg.d_model, dtype)
    layer_fn = functools.partial(_layer_init, cfg=cfg, dtype=dtype)
    if cfg.local_global_ratio:
        R = cfg.local_global_ratio
        G = cfg.n_layers // (R + 1)
        rl, rg = jax.random.split(r_layers)
        local = stack_init(rl, G * R, layer_fn)
        params["local_layers"] = jax.tree.map(
            lambda a: a.reshape(G, R, *a.shape[1:]), local
        )
        params["global_layers"] = stack_init(rg, G, layer_fn)
    else:
        params["layers"] = stack_init(r_layers, cfg.n_layers, layer_fn)
    return params


# ---------------------------------------------------------------------------
# forward core (train / prefill share this)
# ---------------------------------------------------------------------------
def _forward(
    params,
    cfg: ModelConfig,
    x,
    positions,
    *,
    want_cache: bool,
    remat: Optional[str] = None,
    use_kernels: bool = False,
):
    """x: (B, S, d) embedded input. Returns (hidden, cache_arrays, aux)."""
    if cfg.local_global_ratio:
        R = cfg.local_global_ratio
        W = cfg.sliding_window
        g_theta = cfg.global_rope_theta or cfg.rope_theta

        def local_fn(lp, x):
            return _layer_apply(
                lp, x, cfg, positions=positions, theta=cfg.rope_theta,
                window=W, use_kernels=use_kernels,
            )

        def global_fn(lp, x):
            return _layer_apply(
                lp, x, cfg, positions=positions, theta=g_theta,
                window=None, use_kernels=use_kernels,
            )

        local_fn = remat_wrap(local_fn, remat)
        global_fn = remat_wrap(global_fn, remat)

        def block(x, bp):
            lps, gp = bp

            def inner(xc, lp):
                xc, kv, _ = local_fn(lp, xc)
                return xc, kv

            x, lkv = jax.lax.scan(inner, x, lps)
            x, gkv, _ = global_fn(gp, x)
            return x, (lkv, gkv)

        x, (lkvs, gkvs) = jax.lax.scan(
            block, x, (params["local_layers"], params["global_layers"])
        )
        cache = {"local": lkvs, "global": gkvs} if want_cache else None
        return x, cache, 0.0

    def layer_fn(lp, x):
        return _layer_apply(
            lp, x, cfg, positions=positions, theta=cfg.rope_theta,
            window=cfg.sliding_window, use_kernels=use_kernels,
        )

    layer_fn = remat_wrap(layer_fn, remat)

    def body(carry, lp):
        x, aux = carry
        x, kv, a = layer_fn(lp, x)
        return (x, aux + a), kv

    (x, aux), kvs = jax.lax.scan(body, (x, 0.0), params["layers"])
    return x, (kvs if want_cache else None), aux


def _embed_inputs(params, cfg: ModelConfig, batch):
    """Token embedding (+ VLM patch-prefix concat). Returns (x, n_prefix)."""
    x = embed(params["embed"], batch["tokens"])
    if cfg.d_model and cfg.family == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
        return x, batch["patch_embeds"].shape[1]
    return x, 0


def _logits(params, cfg: ModelConfig, h):
    p = params.get("unembed", params["embed"])
    return unembed(p, h)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def loss_fn(params, batch, cfg: ModelConfig, *, remat=None, use_kernels=False):
    x, n_prefix = _embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)
    h, _, aux = _forward(
        params, cfg, x, positions, want_cache=False, remat=remat,
        use_kernels=use_kernels,
    )
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if n_prefix:
        h = h[:, n_prefix:]
    logits = _logits(params, cfg, h)
    ce = cross_entropy_loss(logits, batch["labels"])
    total = ce + MOE_AUX_COEF * aux
    return total, {"ce": ce, "aux": aux}


def prefill(params, batch, S_max: int, cfg: ModelConfig, *, use_kernels=False):
    """Run the prompt, return (last-position logits, decode cache)."""
    x, n_prefix = _embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    dtype = dtype_of(cfg)
    positions = jnp.arange(S)
    h, kvs, _ = _forward(
        params, cfg, x, positions, want_cache=True, use_kernels=use_kernels
    )
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, cfg, h[:, -1])

    if cfg.local_global_ratio:
        W = cfg.sliding_window
        lkv, gkv = kvs["local"], kvs["global"]
        # local layers keep only the trailing window (ring buffer)
        take = min(W, S)
        lk = lkv.k[..., S - take:, :, :]
        lv = lkv.v[..., S - take:, :, :]
        if take < W:
            pad = [(0, 0)] * lk.ndim
            pad[-3] = (0, W - take)
            lk, lv = jnp.pad(lk, pad), jnp.pad(lv, pad)
        ring_pos = jnp.where(
            jnp.arange(W) < take, jnp.arange(W) + (S - take), -1
        ).astype(jnp.int32)
        # global layers get a full-length cache buffer
        def grow(a):
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, S_max - S)
            return jnp.pad(a, pad)
        cache = {
            "lk": lk, "lv": lv, "ring_pos": ring_pos,
            "gk": grow(gkv.k), "gv": grow(gkv.v),
            "pos": jnp.int32(S),
        }
    else:
        def grow(a):
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, S_max - S)
            return jnp.pad(a, pad)
        cache = {"k": grow(kvs.k), "v": grow(kvs.v), "pos": jnp.int32(S)}
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig, *, use_kernels=False):
    """One token for every sequence. batch: {"token": (B,)}."""
    tok = batch["token"]
    x = embed(params["embed"], tok[:, None])
    pos = cache["pos"]
    positions = pos[None]

    if cfg.local_global_ratio:
        W = cfg.sliding_window
        g_theta = cfg.global_rope_theta or cfg.rope_theta
        wp = jnp.mod(pos, W)
        ring_pos = jax.lax.dynamic_update_slice(cache["ring_pos"], pos[None], (wp,))

        def block(x, bp):
            lps, lk, lv, gp, gk, gv = bp

            def inner(xc, inp):
                lp, k1, v1 = inp
                xc, kv, _ = _layer_apply(
                    lp, xc, cfg, positions=positions, theta=cfg.rope_theta,
                    window=W, cache=KVCache(k1, v1), cache_pos=pos,
                    cache_write_pos=wp, kv_positions=ring_pos,
                    use_kernels=use_kernels,
                )
                return xc, kv

            x, lkv = jax.lax.scan(inner, x, (lps, lk, lv))
            x, gkv, _ = _layer_apply(
                gp, x, cfg, positions=positions, theta=g_theta, window=None,
                cache=KVCache(gk, gv), cache_pos=pos, use_kernels=use_kernels,
            )
            return x, (lkv, gkv)

        x, (lkvs, gkvs) = jax.lax.scan(
            block, x,
            (params["local_layers"], cache["lk"], cache["lv"],
             params["global_layers"], cache["gk"], cache["gv"]),
        )
        new_cache = {
            "lk": lkvs.k, "lv": lkvs.v, "ring_pos": ring_pos,
            "gk": gkvs.k, "gv": gkvs.v, "pos": pos + 1,
        }
    else:
        def body(carry, inp):
            x, _ = carry
            lp, k1, v1 = inp
            x, kv, a = _layer_apply(
                lp, x, cfg, positions=positions, theta=cfg.rope_theta,
                window=cfg.sliding_window, cache=KVCache(k1, v1),
                cache_pos=pos, use_kernels=use_kernels,
            )
            return (x, a), kv

        (x, _), kvs = jax.lax.scan(
            body, (x, 0.0), (params["layers"], cache["k"], cache["v"])
        )
        new_cache = {"k": kvs.k, "v": kvs.v, "pos": pos + 1}

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, h[:, 0])
    return logits, new_cache


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    dtype = dtype_of(cfg)
    K, hd = cfg.n_kv_heads, cfg.hd
    if cfg.local_global_ratio:
        R = cfg.local_global_ratio
        G = cfg.n_layers // (R + 1)
        W = cfg.sliding_window
        return {
            "lk": jnp.zeros((G, R, B, W, K, hd), dtype),
            "lv": jnp.zeros((G, R, B, W, K, hd), dtype),
            "ring_pos": jnp.full((W,), -1, jnp.int32),
            "gk": jnp.zeros((G, B, S_max, K, hd), dtype),
            "gv": jnp.zeros((G, B, S_max, K, hd), dtype),
            "pos": jnp.int32(0),
        }
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, B, S_max, K, hd), dtype),
        "v": jnp.zeros((L, B, S_max, K, hd), dtype),
        "pos": jnp.int32(0),
    }


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    extra = None
    if cfg.family == "vlm" and shape.kind != "decode":
        extra = {
            "patch_embeds": jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_patches, cfg.d_model), dtype_of(cfg)
            )
        }
    return token_specs(shape, extra)


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init, cfg=cfg),
        loss=functools.partial(loss_fn, cfg=cfg),
        prefill=functools.partial(prefill, cfg=cfg),
        decode_step=functools.partial(decode_step, cfg=cfg),
        init_cache=functools.partial(init_cache, cfg),
        input_specs=functools.partial(input_specs, cfg),
    )
