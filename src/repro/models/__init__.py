from .common import Model
from .model import build_model

__all__ = ["Model", "build_model"]
