"""GQA attention with causal/sliding-window masks and a decode KV cache.

The compute-heavy paths dispatch to Pallas kernels (``repro.kernels.ops``)
when ``use_kernels`` is on; the pure-jnp path here is the oracle and the
CPU/dry-run path.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, dense, dense_init, head_rmsnorm, rope_tables

_NEG = -2.0e38


class KVCache(NamedTuple):
    """Per-layer decode cache. k/v: (B, S_max, K, hd); pos: scalar int32."""

    k: jnp.ndarray
    v: jnp.ndarray


def attn_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    rq, rk, rv, ro, rn = jax.random.split(rng, 5)
    d, hd = cfg.d_model, cfg.hd
    p = {
        "wq": dense_init(rq, d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(rk, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(rv, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ro, cfg.n_heads * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype=dtype)
        p["k_norm"] = jnp.ones((hd,), dtype=dtype)
    return p


def _mask(
    S_q: int,
    S_k: int,
    *,
    causal: bool,
    window: Optional[int],
    q_offset,
    kv_len=None,
    kv_positions=None,
):
    """(S_q, S_k) additive mask. ``q_offset``: absolute position of query row 0
    (static int or traced scalar). ``kv_len``: valid prefix of the key axis.
    ``kv_positions``: (S_k,) absolute positions of the keys (ring caches);
    negative entries mean 'empty slot'."""
    rows = jnp.arange(S_q)[:, None] + q_offset
    if kv_positions is not None:
        cols = kv_positions[None, :]
        ok = cols >= 0
    else:
        cols = jnp.arange(S_k)[None, :]
        ok = jnp.ones((S_q, S_k), dtype=bool)
    if causal:
        ok &= cols <= rows
    if window is not None:
        ok &= cols > rows - window
    if kv_len is not None:
        ok &= jnp.arange(S_k)[None, :] < kv_len
    return jnp.where(ok, 0.0, _NEG)


def sdpa(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_len=None,
    kv_positions=None,
) -> jnp.ndarray:
    """Reference GQA attention. q: (B,S,H,hd); k/v: (B,T,K,hd); H % K == 0."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    logits = logits + _mask(
        S, T, causal=causal, window=window, q_offset=q_offset,
        kv_len=kv_len, kv_positions=kv_positions,
    )
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, hd)


# blockwise path kicks in at this many KV positions (memory: never
# materialize (S, T) score matrices at 4k+; the Pallas kernel is the TPU
# equivalent, this is the XLA-lowerable one used by dry-runs and grads)
BLOCKWISE_THRESHOLD = 2048


def blockwise_sdpa(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure JAX (lax.scan over KV
    blocks, outer scan over Q blocks). O(S*hd) memory instead of O(S*T)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qc = min(q_chunk, S)
    kc = min(k_chunk, T)
    if S % qc or T % kc:
        return sdpa(q, k, v, causal=causal, window=window, q_offset=q_offset)
    nq, nk = S // qc, T // kc
    scale = hd ** -0.5
    f32 = jnp.float32

    kb = k.reshape(B, nk, kc, K, hd)
    vb = v.reshape(B, nk, kc, K, hd)

    def one_q_block(carry, inp):
        qi, qblk = inp                        # scalar, (B, qc, H, hd)
        qg = qblk.reshape(B, qc, K, G, hd)
        rows = q_offset + qi * qc + jnp.arange(qc)[:, None]

        def kv_body(st, kin):
            ki, kcur, vcur = kin
            m, l, acc = st
            s = jnp.einsum("bskgd,btkd->bkgst", qg, kcur).astype(f32) * scale
            cols = ki * kc + jnp.arange(kc)[None, :]
            ok = jnp.ones((qc, kc), bool)
            if causal:
                ok &= cols <= rows
            if window is not None:
                ok &= cols > rows - window
            s = jnp.where(ok, s, _NEG)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(-1)
            acc = alpha[..., None] * acc + jnp.einsum(
                "bkgst,btkd->bkgsd", p, vcur.astype(f32)
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, K, G, qc), -jnp.inf, f32)
        l0 = jnp.zeros((B, K, G, qc), f32)
        a0 = jnp.zeros((B, K, G, qc, hd), f32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (jnp.arange(nk), kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4)),
        )
        out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, qc, H, hd)
        return carry, out.astype(q.dtype)

    qb = q.reshape(B, nq, qc, H, hd).transpose(1, 0, 2, 3, 4)
    _, outs = jax.lax.scan(one_q_block, (), (jnp.arange(nq), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def attention(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,
    theta: float,
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[KVCache] = None,
    cache_pos=None,
    cache_write_pos=None,
    kv_positions=None,
    kv_override: Optional[tuple] = None,
    use_kernels: bool = False,
):
    """Full attention sub-layer: qkv proj -> rope -> sdpa -> out proj.

    Modes:
      * train/prefill: ``cache is None`` -> attends within x; returns
        (out, KVCache(k, v)) so prefill can keep the cache.
      * decode: ``cache`` given, x is (B, 1, d); keys/values are inserted at
        ``cache_pos`` and attention runs over the cache prefix.
      * cross-attention: ``kv_override=(k, v)`` skips rope/cache.
    """
    from ..hints import constrain

    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    # head-aligned layout: shard heads over "model" when divisible, else
    # replicate — never let GSPMD split hd (see hints.py docstring)
    q = constrain(dense(p["wq"], x).reshape(B, S, H, hd), "dp", None, "model", None)
    if kv_override is None:
        k = constrain(dense(p["wk"], x).reshape(B, S, K, hd), "dp", None, "model", None)
        v = constrain(dense(p["wv"], x).reshape(B, S, K, hd), "dp", None, "model", None)
    else:
        k, v = kv_override

    if cfg.qk_norm:
        q = head_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        if kv_override is None:
            k = head_rmsnorm(p["k_norm"], k, cfg.norm_eps)

    if kv_override is None and theta > 0:
        cos, sin = rope_tables(positions, hd, theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if kv_override is not None:
        out = sdpa(q, k, v, causal=False)
        new_cache = None
    elif cache is None:
        if use_kernels:
            from ..kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=causal, window=window)
        elif S >= BLOCKWISE_THRESHOLD:
            out = blockwise_sdpa(q, k, v, causal=causal, window=window)
        else:
            out = sdpa(q, k, v, causal=causal, window=window)
        new_cache = KVCache(k, v)
    else:
        # decode: write k/v at cache_write_pos (ring caches pass pos % W),
        # attend over the valid region.
        wp = cache_pos if cache_write_pos is None else cache_write_pos
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, wp, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, wp, 0, 0))
        if kv_positions is not None:
            # ring cache: validity comes from the positions array
            out = sdpa(
                q, ck, cv,
                causal=True,
                window=window,
                q_offset=cache_pos,
                kv_positions=kv_positions,
            )
        elif use_kernels:
            from ..kernels import ops as kops
            out = kops.decode_attention(
                q, ck, cv, kv_len=cache_pos + S, window=window
            )
        else:
            out = sdpa(
                q, ck, cv,
                causal=True,
                window=window,
                q_offset=cache_pos,
                kv_len=cache_pos + S,
            )
        new_cache = KVCache(ck, cv)

    y = dense(p["wo"], out.reshape(B, S, H * hd))
    return y, new_cache


def empty_cache(cfg: ModelConfig, B: int, S_max: int, dtype) -> KVCache:
    shape = (B, S_max, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype=dtype), jnp.zeros(shape, dtype=dtype))
