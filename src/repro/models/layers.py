"""Shared neural-net primitives (pure-functional, dict params)."""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# -- init helpers -------------------------------------------------------------
def dense_init(rng, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    w = jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32) * (d_in ** -0.5)
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    w = jax.random.normal(rng, (vocab, d), dtype=jnp.float32) * (d ** -0.5)
    return {"w": w.astype(dtype)}


# -- apply helpers ------------------------------------------------------------
def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm_bf16bwd(scale, x, eps: float = 1e-5):
    """rmsnorm with a bwd that emits cotangents in the INPUT dtype.

    Plain autodiff leaves dx in f32 long enough that XLA hoists the
    bf16 converts above the tensor-parallel all-reduces (measured: 100% of
    train-step collective bytes in f32 = 2x wire cost). Casting dx/partials
    to bf16 inside the VJP pins the converts below the reduces.
    """
    return rmsnorm({"scale": scale}, x, eps)


def _rms_fwd(scale, x, eps):
    return rmsnorm_bf16bwd(scale, x, eps), (scale, x)


def _rms_bwd(eps, res, g):
    scale, x = res
    dt = x.dtype
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
    inv = jax.lax.rsqrt(var)
    gs = gf * scale.astype(jnp.float32)
    # d/dx [x * inv(x)]: inv * (gs - x * mean(gs * x) / var)
    proj = jnp.mean(gs * xf, axis=-1, keepdims=True) / var
    dx = (inv * (gs - xf * proj)).astype(dt)          # cast BEFORE the AR
    dscale = jnp.sum(gf * xf * inv, axis=tuple(range(x.ndim - 1)))
    return dscale.astype(scale.dtype), dx


rmsnorm_bf16bwd.defvjp(_rms_fwd, _rms_bwd)


def head_rmsnorm(scale, x, eps: float = 1e-5):
    """qk-norm: normalize over the head dim. x: (..., hd), scale: (hd,)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def embed(p, tokens):
    return jnp.take(p["w"], tokens, axis=0)


def unembed(p, x):
    """Project to vocab. p is the embed table when tied ((V, d)) or an
    unembed matrix ((d, V))."""
    w = p["w"]
    if w.shape[0] == x.shape[-1]:
        return x @ w
    return x @ w.T


def swiglu_init(rng, d: int, d_ff: int, dtype=jnp.float32):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "gate": dense_init(r1, d, d_ff, dtype=dtype),
        "up": dense_init(r2, d, d_ff, dtype=dtype),
        "down": dense_init(r3, d_ff, d, dtype=dtype),
    }


def swiglu(p, x):
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


# -- rotary embeddings ---------------------------------------------------------
def rope_tables(positions: jnp.ndarray, hd: int, theta: float):
    """positions: (S,) int -> cos/sin (S, hd/2), float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, hd); cos/sin: (S, hd/2)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def sinusoid_embed(S: int, d: int):
    """Whisper-style fixed sinusoidal positional embeddings (S, d)."""
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x, cap: Optional[float]):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def cross_entropy_loss(logits, labels, *, ignore_index: int = -100):
    """Mean next-token CE over non-ignored positions. logits (B,S,V)."""
    valid = labels != ignore_index
    labels_safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels_safe[..., None], axis=-1
    )[..., 0]
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def norm(p, x, eps: float = 1e-5):
    """rmsnorm, switching to the bf16-cotangent VJP under the bf16bwd flag."""
    from ..hints import flag
    if flag("bf16bwd"):
        return rmsnorm_bf16bwd(p["scale"], x, eps)
    return rmsnorm(p, x, eps)
