"""Mixture-of-Experts FFN with top-k routing and capacity-bounded, sort-based
dispatch (GShard/Switch lineage, MaxText-style sort dispatch).

Design for TPU/pjit:
  * each batch row is a dispatch group (G = B): per-row capacity
    ``C = ceil(S * k * capacity_factor / E)``;
  * dispatch is index-based (argsort by expert id + bounded slots), not a
    (tokens x E x C) one-hot einsum — the one-hot would not fit VMEM/HBM at
    our shapes;
  * the dispatch buffer is (B, E, C, d); contracting with expert weights
    (E, d, f) forces E-sharding over the "model" axis, so XLA inserts the
    dispatch all-to-all at the (B-sharded -> E-sharded) boundary;
  * dropped tokens (over capacity) fall into a trash slot and contribute 0.

Returns the standard load-balance auxiliary loss (Switch §2.2):
``aux = E * sum_e f_e * P_e``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init


def moe_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    r0, r1, r2, r3 = jax.random.split(rng, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale_in = d ** -0.5
    scale_out = f ** -0.5
    return {
        "router": dense_init(r0, d, E, dtype=jnp.float32),
        "gate": (jax.random.normal(r1, (E, d, f)) * scale_in).astype(dtype),
        "up": (jax.random.normal(r2, (E, d, f)) * scale_in).astype(dtype),
        "down": (jax.random.normal(r3, (E, f, d)) * scale_out).astype(dtype),
    }


def _capacity(S: int, cfg: ModelConfig) -> int:
    c = int(-(-S * cfg.experts_per_token * cfg.moe_capacity_factor // cfg.n_experts))
    return max(1, c)


def _dispatch_row(xr, idx, E: int, C: int):
    """Per-row dispatch plan. xr: (S, d); idx: (S, k) expert ids.

    Returns (buf (E, C, d), slot_of_dispatch (S*k,)) where slot == E*C means
    dropped.
    """
    S, k = idx.shape
    d = xr.shape[-1]
    n = S * k
    eid = idx.reshape(n)
    order = jnp.argsort(eid)                      # stable
    sorted_eid = eid[order]
    counts = jnp.bincount(eid, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(n, dtype=jnp.int32) - starts[sorted_eid].astype(jnp.int32)
    keep = pos_in_e < C
    slot_sorted = jnp.where(keep, sorted_eid * C + pos_in_e, E * C)
    tok_sorted = (order // k).astype(jnp.int32)
    # slot -> source token (drops land in the trash slot E*C)
    slot_tok = jnp.full((E * C + 1,), S, dtype=jnp.int32).at[slot_sorted].set(tok_sorted)
    slot_tok = slot_tok[: E * C]
    xpad = jnp.concatenate([xr, jnp.zeros((1, d), dtype=xr.dtype)], axis=0)
    buf = xpad[slot_tok].reshape(E, C, d)
    slot_of_dispatch = jnp.zeros((n,), dtype=jnp.int32).at[order].set(slot_sorted)
    return buf, slot_of_dispatch


def moe_ffn(p, x: jnp.ndarray, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = _capacity(S, cfg)

    logits = (x.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # (B, S, E)
    w, idx = jax.lax.top_k(probs, k)                        # (B, S, k)
    w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    from ..hints import constrain, flag

    buf, slots = jax.vmap(lambda xr, ir: _dispatch_row(xr, ir, E, C))(x, idx)
    if flag("moe2d"):
        # 2D dispatch (hillclimb, see EXPERIMENTS.md §Perf): keep the buffer
        # (B, E, C, d) sharded (dp, model) THROUGHOUT — every device computes
        # its expert shard on its batch shard, so the dispatch needs no
        # collective at all; only the combine gathers expert outputs over
        # "model". Avoids GSPMD's replicate-then-slice when resharding from
        # the data axis (dim 0) to the model axis (dim 1).
        buf = constrain(buf, "dp", "model", None, None)
        h = jnp.einsum("becd,edf->becf", buf, p["gate"])
        u = jnp.einsum("becd,edf->becf", buf, p["up"])
        out = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u, p["down"])
        out = constrain(out, "dp", None, None, None)
        out = out.reshape(B, E * C, d)
    else:
        # baseline (GShard-style): reshard (B,E,C,d) -> (E, B*C, d)
        buf = constrain(buf, "dp", None, None, None)
        buf = buf.transpose(1, 0, 2, 3).reshape(E, B * C, d)
        buf = constrain(buf, "model", None, None)

        h = jnp.einsum("ecd,edf->ecf", buf, p["gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, p["up"])
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["down"])

        # back to (B, E*C, d) + trash row, then combine per dispatch slot
        out = out.reshape(E, B, C, d).transpose(1, 0, 2, 3).reshape(B, E * C, d)
        out = constrain(out, "dp", None, None)
    out = jnp.concatenate([out, jnp.zeros((B, 1, d), dtype=out.dtype)], axis=1)
    y_rep = jnp.take_along_axis(out, slots[..., None].astype(jnp.int32), axis=1)
    y = (y_rep.reshape(B, S, k, d) * w[..., None]).sum(axis=2)

    # Switch-style load-balance aux (fraction routed x mean prob).
    f_e = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (B * S * k)
    p_e = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e)
    return y, aux
