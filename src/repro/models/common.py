"""Shared assembly utilities: stacked-layer init, remat policies, loss."""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec


def stack_init(rng, n: int, fn: Callable):
    """vmap ``fn(rng) -> params`` over ``n`` fresh rngs -> stacked params."""
    return jax.vmap(fn)(jax.random.split(rng, n))


def remat_wrap(fn: Callable, policy: Optional[str]):
    if policy is None or policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn, policy=None)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {policy!r}")


class Model(NamedTuple):
    """Uniform per-family API (closures over a ModelConfig)."""

    cfg: ModelConfig
    init: Callable                    # (rng) -> params
    loss: Callable                    # (params, batch) -> (loss, metrics)
    prefill: Callable                 # (params, batch, S_max) -> (logits, cache)
    decode_step: Callable             # (params, cache, batch) -> (logits, cache)
    init_cache: Callable              # (B, S_max) -> cache pytree
    input_specs: Callable             # (ShapeSpec) -> dict of ShapeDtypeStruct


def token_specs(shape: ShapeSpec, extra: dict | None = None) -> dict:
    """Input ShapeDtypeStructs for LM-style batches (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        d = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    elif shape.kind == "prefill":
        d = {"tokens": sds((B, S), i32)}
    else:  # decode: one new token against an S-long cache
        d = {"token": sds((B,), i32)}
    if extra:
        d.update(extra)
    return d
