"""Family dispatch: build a Model from any ModelConfig."""

from __future__ import annotations

from ..configs.base import ModelConfig
from .common import Model


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        from . import transformer
        return transformer.build(cfg)
    if cfg.family == "hybrid":
        from . import hybrid
        return hybrid.build(cfg)
    if cfg.family == "ssm":
        from . import xlstm_model
        return xlstm_model.build(cfg)
    if cfg.family == "audio":
        from . import encdec
        return encdec.build(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
