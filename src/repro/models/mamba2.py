"""Mamba2 (SSD) mixer — chunked parallel form for train/prefill, O(1)-state
recurrent form for decode (arXiv:2405.21060, adapted to TPU: chunk size is
MXU-aligned, intra-chunk term is a (Q x Q) matmul, inter-chunk term is a
``lax.scan`` over chunk states).

Recurrence (heads H, head dim P, state N, chunk Q):
    h_t = a_t * h_{t-1} + dt_t * (B_t ⊗ x_t)        h: (H, P, N)
    y_t = C_t · h_t + D * x_t
with a_t = exp(dt_t * A), A = -exp(A_log) < 0.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense, dense_init, rmsnorm


class MambaCache(NamedTuple):
    conv: jnp.ndarray    # (B, W-1, conv_channels) rolling conv input window
    h: jnp.ndarray       # (B, H, P, N) SSM state


def mamba_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.d_inner_ssm
    N = cfg.ssm_state
    H = cfg.n_ssm_heads
    W = cfg.ssm_conv_width
    conv_ch = di + 2 * N
    r0, r1, r2, r3 = jax.random.split(rng, 4)
    return {
        "in_proj": dense_init(r0, d, 2 * di + 2 * N + H, dtype=dtype),
        "conv_w": (jax.random.normal(r1, (W, conv_ch)) * (W ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "A_log": jnp.zeros((H,), dtype=jnp.float32),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "gnorm": {"scale": jnp.ones((di,), dtype=dtype)},
        "out_proj": dense_init(r3, di, d, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, Ch); w: (W, Ch)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp,
        w[:, None, :],
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def ssd_chunked(x, dt, A, B_in, C_in, Q: int, h0=None, *, use_kernel: bool = False):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    B_in/C_in: (B, S, N) (single group, shared across heads).
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    With ``use_kernel`` the intra-chunk quadratic work runs in the Pallas
    kernel (``kernels/ssd_scan.py``); the inter-chunk scan stays here.
    """
    Bsz, S, H, P = x.shape
    N = B_in.shape[-1]
    S_orig = S
    if S % Q:
        # pad tail with identity steps: dt=0 -> a=1, xbar=0 -> state unchanged
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_in = jnp.pad(B_in, ((0, 0), (0, pad), (0, 0)))
        C_in = jnp.pad(C_in, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    f32 = jnp.float32

    la = (dt * A).astype(f32)                           # log a_t  (B,S,H)
    xbar = (dt[..., None] * x).astype(f32)              # (B,S,H,P)
    xc = xbar.reshape(Bsz, nc, Q, H, P)
    Bc = B_in.reshape(Bsz, nc, Q, N).astype(f32)
    Cc = C_in.reshape(Bsz, nc, Q, N).astype(f32)
    lac = la.reshape(Bsz, nc, Q, H)
    L = jnp.cumsum(lac, axis=2)                         # (B,nc,Q,H)
    Ltot = L[:, :, -1, :]                               # (B,nc,H)

    if use_kernel:
        from ..kernels import ops as kops
        y_intra, states, _ = kops.ssd_intra_chunk(lac, Cc, Bc, xc)
    else:
        # intra-chunk: y[t] = sum_{s<=t} exp(L_t - L_s) (C_t.B_s) xbar_s
        CB = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)      # (B,nc,Q,Q)
        seg = L[:, :, :, None, :] - L[:, :, None, :, :]  # (B,nc,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), dtype=bool))
        M = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
        M = M * CB[..., None]                           # (B,nc,Q,Q,H)
        y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", M, xc)

        # chunk states: S_c = sum_s exp(Ltot - L_s) xbar_s ⊗ B_s
        w_end = jnp.exp(Ltot[:, :, None, :] - L)        # (B,nc,Q,H)
        states = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", w_end, xc, Bc)

    # inter-chunk scan over h
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), dtype=f32)

    def body(h, inp):
        st, ltot = inp                                  # (B,H,P,N), (B,H)
        h_out = h                                       # state *entering* chunk
        h_new = jnp.exp(ltot)[:, :, None, None] * h + st
        return h_new, h_out

    hT, h_prevs = jax.lax.scan(
        body,
        h0,
        (states.transpose(1, 0, 2, 3, 4), Ltot.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (B,nc,H,P,N)

    # y_inter[t] = exp(L_t) * C_t · h_prev
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp", jnp.exp(L), Cc, h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)[:, :S_orig]
    return y.astype(x.dtype), hT


def mamba_forward(p, x, cfg: ModelConfig, *, cache: MambaCache | None = None,
                  use_kernels: bool = False):
    """One mamba2 mixer. x: (B, S, d). With ``cache`` (decode) S must be 1."""
    Bsz, S, d = x.shape
    di = cfg.d_inner_ssm
    N = cfg.ssm_state
    H = cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    W = cfg.ssm_conv_width

    zxbcdt = dense(p["in_proj"], x)
    z, xBC, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)

    if cache is None:
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        new_conv = None
    else:
        window = jnp.concatenate([cache.conv, xBC], axis=1)     # (B, W, Ch)
        conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
        xBC = jax.nn.silu(conv_out)[:, None, :]
        new_conv = window[:, 1:, :]

    from ..hints import constrain

    xs, B_in, C_in = jnp.split(xBC, [di, di + N], axis=-1)
    xs = constrain(xs.reshape(Bsz, S, H, P), "dp", None, "model", None)
    B_in = constrain(B_in, "dp", None, None)
    C_in = constrain(C_in, "dp", None, None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cache is None:
        y, hT = ssd_chunked(xs, dt, A, B_in, C_in, cfg.ssm_chunk,
                            use_kernel=use_kernels)
        new_cache = None
    else:
        a = jnp.exp(dt * A)                                     # (B,1,H)
        xbar = (dt[..., None] * xs).astype(jnp.float32)         # (B,1,H,P)
        dh = jnp.einsum("bhp,bn->bhpn", xbar[:, 0], B_in[:, 0].astype(jnp.float32))
        h = a[:, 0, :, None, None] * cache.h + dh
        y = jnp.einsum("bn,bhpn->bhp", C_in[:, 0].astype(jnp.float32), h)
        y = y[:, None].astype(x.dtype)
        new_cache = MambaCache(conv=new_conv, h=h)

    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(Bsz, S, di)
    y = rmsnorm(p["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y)
    return out, new_cache


def empty_mamba_cache(cfg: ModelConfig, B: int, dtype) -> MambaCache:
    di, N, H, P, W = (
        cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim,
        cfg.ssm_conv_width,
    )
    return MambaCache(
        conv=jnp.zeros((B, W - 1, di + 2 * N), dtype=dtype),
        h=jnp.zeros((B, H, P, N), dtype=jnp.float32),
    )
