"""Whisper-style encoder-decoder. The conv/mel frontend is a STUB per the
brief: ``input_specs`` provides precomputed frame embeddings (B, enc_seq, d).

Adaptations noted in DESIGN.md: sinusoidal absolute embeddings on the encoder
(as Whisper), RoPE in the decoder self-attention (instead of Whisper's learned
448-entry table, which cannot address the assigned 32k/500k decode shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from .attention import KVCache, attention, attn_init
from .common import Model, remat_wrap, stack_init, token_specs
from .layers import (
    cross_entropy_loss,
    dense,
    dtype_of,
    embed,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    sinusoid_embed,
    swiglu,
    swiglu_init,
    unembed,
)


def _enc_layer_init(rng, cfg, dtype):
    ra, rm = jax.random.split(rng)
    return {
        "attn": attn_init(ra, cfg, dtype=dtype),
        "mlp": swiglu_init(rm, cfg.d_model, cfg.d_ff, dtype=dtype),
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }


def _dec_layer_init(rng, cfg, dtype):
    ra, rc, rm = jax.random.split(rng, 3)
    return {
        "self_attn": attn_init(ra, cfg, dtype=dtype),
        "cross_attn": attn_init(rc, cfg, dtype=dtype),
        "mlp": swiglu_init(rm, cfg.d_model, cfg.d_ff, dtype=dtype),
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "lnc": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }


def init(rng, cfg: ModelConfig):
    dtype = dtype_of(cfg)
    r_emb, r_enc, r_dec, r_un = jax.random.split(rng, 4)
    return {
        "embed": embed_init(r_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "unembed": embed_init(r_un, cfg.padded_vocab, cfg.d_model, dtype),
        "enc_layers": stack_init(
            r_enc, cfg.encoder_layers,
            functools.partial(_enc_layer_init, cfg=cfg, dtype=dtype),
        ),
        "dec_layers": stack_init(
            r_dec, cfg.n_layers,
            functools.partial(_dec_layer_init, cfg=cfg, dtype=dtype),
        ),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def encode(params, cfg: ModelConfig, frames, *, remat=None):
    """frames: (B, T_enc, d) precomputed frame embeddings (frontend stub)."""
    T = frames.shape[1]
    x = frames + sinusoid_embed(T, cfg.d_model).astype(frames.dtype)
    positions = jnp.arange(T)

    def layer(lp, x):
        h, _ = attention(
            lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
            positions=positions, theta=0.0, causal=False,
        )
        x = x + h
        return x + swiglu(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))

    layer = remat_wrap(layer, remat)

    def body(x, lp):
        return layer(lp, x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(
    lp, x, cfg, *, positions, enc_kv=None, enc_out=None,
    cache=None, cache_pos=None,
):
    h, kv = attention(
        lp["self_attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, theta=cfg.rope_theta,
        cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    xc = rmsnorm(lp["lnc"], x, cfg.norm_eps)
    if enc_kv is None:
        K, hd = cfg.n_kv_heads, cfg.hd
        B, T = enc_out.shape[:2]
        ek = dense(lp["cross_attn"]["wk"], enc_out).reshape(B, T, K, hd)
        ev = dense(lp["cross_attn"]["wv"], enc_out).reshape(B, T, K, hd)
        enc_kv = (ek, ev)
    h, _ = attention(
        lp["cross_attn"], xc, cfg, positions=positions, theta=0.0,
        kv_override=enc_kv,
    )
    x = x + h
    x = x + swiglu(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return x, kv, enc_kv


def loss_fn(params, batch, cfg: ModelConfig, *, remat=None, use_kernels=False):
    enc_out = encode(params, cfg, batch["frames"], remat=remat)
    x = embed(params["embed"], batch["tokens"])
    S = x.shape[1]
    positions = jnp.arange(S)

    def layer(lp, x):
        x, _, _ = _dec_layer(lp, x, cfg, positions=positions, enc_out=enc_out)
        return x

    layer = remat_wrap(layer, remat)

    def body(x, lp):
        return layer(lp, x), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], h)
    ce = cross_entropy_loss(logits, batch["labels"])
    return ce, {"ce": ce, "aux": 0.0}


def prefill(params, batch, S_max: int, cfg: ModelConfig, *, use_kernels=False):
    enc_out = encode(params, cfg, batch["frames"])
    x = embed(params["embed"], batch["tokens"])
    B, S = x.shape[:2]
    positions = jnp.arange(S)

    def body(x, lp):
        x, kv, enc_kv = _dec_layer(lp, x, cfg, positions=positions, enc_out=enc_out)
        return x, (kv, enc_kv)

    x, (kvs, enc_kvs) = jax.lax.scan(body, x, params["dec_layers"])
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], h[:, -1])

    def grow(a):
        pad = [(0, 0)] * a.ndim
        pad[-3] = (0, S_max - S)
        return jnp.pad(a, pad)

    cache = {
        "k": grow(kvs.k), "v": grow(kvs.v),
        "ck": enc_kvs[0], "cv": enc_kvs[1],
        "pos": jnp.int32(S),
    }
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig, *, use_kernels=False):
    x = embed(params["embed"], batch["token"][:, None])
    pos = cache["pos"]
    positions = pos[None]

    def body(x, inp):
        lp, k1, v1, ck, cv = inp
        x, kv, _ = _dec_layer(
            lp, x, cfg, positions=positions, enc_kv=(ck, cv),
            cache=KVCache(k1, v1), cache_pos=pos,
        )
        return x, kv

    x, kvs = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["ck"], cache["cv"]),
    )
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], h[:, 0])
    new_cache = dict(cache, k=kvs.k, v=kvs.v, pos=pos + 1)
    return logits, new_cache


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    dtype = dtype_of(cfg)
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, B, S_max, K, hd), dtype),
        "v": jnp.zeros((L, B, S_max, K, hd), dtype),
        "ck": jnp.zeros((L, B, cfg.encoder_seq, K, hd), dtype),
        "cv": jnp.zeros((L, B, cfg.encoder_seq, K, hd), dtype),
        "pos": jnp.int32(0),
    }


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    extra = None
    if shape.kind != "decode":
        extra = {
            "frames": jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.encoder_seq, cfg.d_model), dtype_of(cfg)
            )
        }
    return token_specs(shape, extra)


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init, cfg=cfg),
        loss=functools.partial(loss_fn, cfg=cfg),
        prefill=functools.partial(prefill, cfg=cfg),
        decode_step=functools.partial(decode_step, cfg=cfg),
        init_cache=functools.partial(init_cache, cfg),
        input_specs=functools.partial(input_specs, cfg),
    )
