"""Zamba2-style hybrid: Mamba2 backbone with a single weight-shared
attention+MLP block applied after every ``shared_attn_every`` mamba layers.

Simplification vs. the released Zamba2 (noted in DESIGN.md): the shared block
consumes the residual stream directly (no concat with the original embedding,
no per-invocation LoRA). Structure (mamba backbone + periodically-invoked
tied attention with its own KV cache per invocation site) is preserved.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from .attention import KVCache, attention, attn_init
from .common import Model, remat_wrap, stack_init, token_specs
from .layers import (
    cross_entropy_loss,
    dtype_of,
    embed,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
    unembed,
)
from .mamba2 import MambaCache, empty_mamba_cache, mamba_forward, mamba_init


def _groups(cfg: ModelConfig) -> tuple[int, int, int]:
    gs = cfg.shared_attn_every
    ng = cfg.n_layers // gs
    tail = cfg.n_layers - ng * gs
    return ng, gs, tail


def _mamba_layer_init(rng, cfg, dtype):
    return {
        "norm": rmsnorm_init(cfg.d_model, dtype),
        "mamba": mamba_init(rng, cfg, dtype=dtype),
    }


def _mamba_layer(lp, x, cfg, cache=None, use_kernels=False):
    h, new_cache = mamba_forward(
        lp["mamba"], rmsnorm(lp["norm"], x, cfg.norm_eps), cfg, cache=cache,
        use_kernels=use_kernels,
    )
    return x + h, new_cache


def _shared_apply(sp, x, cfg, *, positions, cache=None, cache_pos=None):
    h, kv = attention(
        sp["attn"], rmsnorm(sp["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, theta=cfg.rope_theta,
        cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    x = x + swiglu(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps))
    return x, kv


def init(rng, cfg: ModelConfig):
    dtype = dtype_of(cfg)
    ng, gs, tail = _groups(cfg)
    r_emb, r_m, r_t, r_s, r_un = jax.random.split(rng, 5)
    layer_fn = functools.partial(_mamba_layer_init, cfg=cfg, dtype=dtype)
    grouped = stack_init(r_m, ng * gs, layer_fn)
    params = {
        "embed": embed_init(r_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "mamba_groups": jax.tree.map(
            lambda a: a.reshape(ng, gs, *a.shape[1:]), grouped
        ),
        "shared": {
            "attn": attn_init(r_s, cfg, dtype=dtype),
            "mlp": swiglu_init(jax.random.fold_in(r_s, 1), cfg.d_model, cfg.d_ff, dtype=dtype),
            "ln1": rmsnorm_init(cfg.d_model, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
        },
    }
    if tail:
        params["mamba_tail"] = stack_init(r_t, tail, layer_fn)
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(r_un, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def _forward(params, cfg, x, positions, *, want_cache: bool, remat=None,
             use_kernels=False):
    shared = params["shared"]
    m_layer = remat_wrap(
        functools.partial(_mamba_layer, cfg=cfg, use_kernels=use_kernels), remat
    )

    def group(x, gp):
        def inner(xc, lp):
            xc, _ = m_layer(lp, xc)
            return xc, None

        x, _ = jax.lax.scan(inner, x, gp)
        x, kv = _shared_apply(shared, x, cfg, positions=positions)
        return x, kv

    x, skv = jax.lax.scan(group, x, params["mamba_groups"])
    if "mamba_tail" in params:
        def inner(xc, lp):
            xc, _ = m_layer(lp, xc)
            return xc, None
        x, _ = jax.lax.scan(inner, x, params["mamba_tail"])
    return x, (skv if want_cache else None)


def loss_fn(params, batch, cfg: ModelConfig, *, remat=None, use_kernels=False):
    x = embed(params["embed"], batch["tokens"])
    S = x.shape[1]
    h, _ = _forward(params, cfg, x, jnp.arange(S), want_cache=False, remat=remat,
                    use_kernels=use_kernels)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params.get("unembed", params["embed"]), h)
    ce = cross_entropy_loss(logits, batch["labels"])
    return ce, {"ce": ce, "aux": 0.0}


def prefill(params, batch, S_max: int, cfg: ModelConfig, *, use_kernels=False):
    """Prefill must also produce mamba states -> run layers with streaming
    semantics: chunked SSD already yields the final state, so we re-run the
    group scan keeping states."""
    x = embed(params["embed"], batch["tokens"])
    B, S = x.shape[:2]
    positions = jnp.arange(S)
    shared = params["shared"]
    dtype = dtype_of(cfg)

    def m_layer_with_state(lp, xc):
        xn = rmsnorm(lp["norm"], xc, cfg.norm_eps)
        # run chunked and also extract final conv window + ssm state
        from .mamba2 import _causal_conv, ssd_chunked
        from .layers import dense as _dense
        di, N, H, P, W = (
            cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads,
            cfg.ssm_head_dim, cfg.ssm_conv_width,
        )
        zxbcdt = _dense(lp["mamba"]["in_proj"], xn)
        z, xBC, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
        conv_tail = xBC[:, -(W - 1):, :]
        xBC_c = jax.nn.silu(_causal_conv(xBC, lp["mamba"]["conv_w"], lp["mamba"]["conv_b"]))
        from ..hints import constrain
        xs, B_in, C_in = jnp.split(xBC_c, [di, di + N], axis=-1)
        xs = constrain(xs.reshape(B, S, H, P), "dp", None, "model", None)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["mamba"]["dt_bias"])
        A = -jnp.exp(lp["mamba"]["A_log"])
        y, hT = ssd_chunked(xs, dt, A, B_in, C_in, cfg.ssm_chunk)
        y = y + lp["mamba"]["D"].astype(y.dtype)[None, None, :, None] * xs
        y = y.reshape(B, S, di)
        y = rmsnorm(lp["mamba"]["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
        out = _dense(lp["mamba"]["out_proj"], y)
        return xc + out, MambaCache(conv=conv_tail, h=hT)

    def group(x, gp):
        def inner(xc, lp):
            xc, st = m_layer_with_state(lp, xc)
            return xc, st

        x, states = jax.lax.scan(inner, x, gp)
        x, kv = _shared_apply(shared, x, cfg, positions=positions)
        return x, (states, kv)

    x, (g_states, skv) = jax.lax.scan(group, x, params["mamba_groups"])
    t_states = None
    if "mamba_tail" in params:
        def inner(xc, lp):
            xc, st = m_layer_with_state(lp, xc)
            return xc, st
        x, t_states = jax.lax.scan(inner, x, params["mamba_tail"])

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("unembed", params["embed"]), h[:, -1])

    def grow(a):
        pad = [(0, 0)] * a.ndim
        pad[-3] = (0, S_max - S)
        return jnp.pad(a, pad)

    cache = {
        "g_conv": g_states.conv, "g_h": g_states.h,
        "sk": grow(skv.k), "sv": grow(skv.v),
        "pos": jnp.int32(S),
    }
    if t_states is not None:
        cache["t_conv"], cache["t_h"] = t_states.conv, t_states.h
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig, *, use_kernels=False):
    x = embed(params["embed"], batch["token"][:, None])
    pos = cache["pos"]
    positions = pos[None]
    shared = params["shared"]

    def group(x, gp):
        lps, conv, h, k1, v1 = gp

        def inner(xc, inp):
            lp, c, hh = inp
            xc, st = _mamba_layer(lp, xc, cfg, cache=MambaCache(c, hh))
            return xc, st

        x, states = jax.lax.scan(inner, x, (lps, conv, h))
        x, kv = _shared_apply(shared, x, cfg, positions=positions,
                              cache=KVCache(k1, v1), cache_pos=pos)
        return x, (states, kv)

    x, (g_states, skv) = jax.lax.scan(
        group, x,
        (params["mamba_groups"], cache["g_conv"], cache["g_h"],
         cache["sk"], cache["sv"]),
    )
    new_cache = {
        "g_conv": g_states.conv, "g_h": g_states.h,
        "sk": skv.k, "sv": skv.v, "pos": pos + 1,
    }
    if "mamba_tail" in params:
        def inner(xc, inp):
            lp, c, hh = inp
            xc, st = _mamba_layer(lp, xc, cfg, cache=MambaCache(c, hh))
            return xc, st
        x, t_states = jax.lax.scan(
            inner, x, (params["mamba_tail"], cache["t_conv"], cache["t_h"])
        )
        new_cache["t_conv"], new_cache["t_h"] = t_states.conv, t_states.h

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params.get("unembed", params["embed"]), h[:, 0])
    return logits, new_cache


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    dtype = dtype_of(cfg)
    ng, gs, tail = _groups(cfg)
    mc = empty_mamba_cache(cfg, B, dtype)

    def rep(a, n):
        return jnp.broadcast_to(a, (n,) + a.shape).copy() if n else None

    def rep2(a):
        return jnp.broadcast_to(a, (ng, gs) + a.shape).copy()

    K, hd = cfg.n_kv_heads, cfg.hd
    cache = {
        "g_conv": rep2(mc.conv), "g_h": rep2(mc.h),
        "sk": jnp.zeros((ng, B, S_max, K, hd), dtype),
        "sv": jnp.zeros((ng, B, S_max, K, hd), dtype),
        "pos": jnp.int32(0),
    }
    if tail:
        cache["t_conv"] = rep(mc.conv, tail)
        cache["t_h"] = rep(mc.h, tail)
    return cache


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return token_specs(shape)


def build(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init, cfg=cfg),
        loss=functools.partial(loss_fn, cfg=cfg),
        prefill=functools.partial(prefill, cfg=cfg),
        decode_step=functools.partial(decode_step, cfg=cfg),
        init_cache=functools.partial(init_cache, cfg),
        input_specs=functools.partial(input_specs, cfg),
    )
