"""InternVL2-2B [arXiv:2404.16821]: InternViT frontend (STUB: precomputed
patch embeddings per the brief) + InternLM2 LM backbone."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1e6,
    n_patches=256,
)
SMOKE = reduced(CONFIG)
