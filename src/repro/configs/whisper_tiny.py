"""Whisper-tiny [arXiv:2212.04356]: encoder-decoder; conv/mel frontend is a
STUB (input_specs provides precomputed 1500-frame embeddings)."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,               # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
)
SMOKE = reduced(CONFIG)
