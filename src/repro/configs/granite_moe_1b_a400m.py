"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]:
32 experts, top-8."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                # per-expert FFN width
    vocab_size=49155,
    n_experts=32,
    experts_per_token=8,
    tie_embeddings=True,
)
SMOKE = reduced(CONFIG)
