"""Gemma3-12B [hf:google/gemma-3 family]: 5 local(1024-window):1 global
attention pattern, qk-norm, dual rope theta."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1e4,           # local layers
    global_rope_theta=1e6,    # global layers
    qk_norm=True,
    sliding_window=1024,
    local_global_ratio=5,
    tie_embeddings=True,
)
SMOKE = reduced(CONFIG)
