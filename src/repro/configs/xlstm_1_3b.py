"""xLSTM-1.3B [arXiv:2405.04517]: mLSTM (matrix memory) blocks with one
sLSTM block every 8 layers; no separate FFN (projections live in-block)."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    ssm_head_dim=512,        # d_inner / n_heads = 4096 / 8... heads are config.n_heads
    slstm_period=8,
    tie_embeddings=True,
)
SMOKE = reduced(CONFIG, n_heads=4, n_kv_heads=4, ssm_head_dim=64)
