"""Qwen2.5-32B [hf:Qwen/Qwen2.5 family]: dense GQA with QKV bias."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    rope_theta=1e6,
    qkv_bias=True,
)
SMOKE = reduced(CONFIG)
