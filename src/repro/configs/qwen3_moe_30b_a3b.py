"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 128 experts, top-8, qk-norm."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                # per-expert FFN width
    vocab_size=151936,
    rope_theta=1e6,
    qk_norm=True,
    n_experts=128,
    experts_per_token=8,
)
SMOKE = reduced(CONFIG)
