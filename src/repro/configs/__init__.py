from .base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    LONG_CONTEXT_ARCHS,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeSpec,
    reduced,
    shapes_for,
)
from .registry import ARCH_IDS, all_cells, cells, get_config, get_smoke

__all__ = [
    "ALL_SHAPES", "DECODE_32K", "LONG_500K", "LONG_CONTEXT_ARCHS",
    "PREFILL_32K", "TRAIN_4K", "ModelConfig", "ShapeSpec", "reduced",
    "shapes_for", "ARCH_IDS", "all_cells", "cells", "get_config", "get_smoke",
]
