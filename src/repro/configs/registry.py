"""--arch id -> config registry."""

from __future__ import annotations

import importlib

from .base import ModelConfig, ShapeSpec, shapes_for

_MODULES = {
    "internvl2-2b": "internvl2_2b",
    "zamba2-7b": "zamba2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "gemma3-12b": "gemma3_12b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-14b": "qwen3_14b",
    "xlstm-1.3b": "xlstm_1_3b",
    "whisper-tiny": "whisper_tiny",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def cells(arch_id: str) -> tuple[tuple[ModelConfig, ShapeSpec], ...]:
    cfg = get_config(arch_id)
    return tuple((cfg, s) for s in shapes_for(arch_id))


def all_cells() -> list[tuple[str, ShapeSpec]]:
    return [(a, s) for a in ARCH_IDS for s in shapes_for(a)]
