"""Model/shape configuration system.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact published numbers) and ``SMOKE`` (a reduced same-family
variant that runs a forward/train step on CPU). ``registry.py`` maps the
``--arch`` ids to modules.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention details
    rope_theta: float = 1e4
    global_rope_theta: Optional[float] = None   # gemma3 global layers
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None        # window for local layers
    local_global_ratio: int = 0                 # gemma3: 5 (locals per global)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM / hybrid (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    shared_attn_every: int = 0                  # zamba2: shared attn block period

    # xLSTM
    slstm_period: int = 0                       # 1 sLSTM per this many layers

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                        # precomputed frame embeddings (stub)

    # VLM (internvl2)
    n_patches: int = 0                          # precomputed patch embeddings (stub)

    # numerics
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"                     # activations/params compute dtype

    def __post_init__(self) -> None:
        if self.n_heads % max(1, self.n_kv_heads):
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.family == "moe" and (self.n_experts <= 0 or self.experts_per_token <= 0):
            raise ValueError("moe family needs n_experts/experts_per_token")

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so embedding/unembedding
        shard cleanly over any TP degree <= 256 (pad ids are never targets)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for roofline math."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        dense_mlp = 3 * d * self.d_ff
        n = emb
        if self.family in ("dense", "vlm"):
            n += self.n_layers * (attn + dense_mlp + 2 * d)
        elif self.family == "moe":
            moe_mlp = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            n += self.n_layers * (attn + moe_mlp + 2 * d)
        elif self.family == "hybrid":
            di, N, H = self.d_inner_ssm, self.ssm_state, self.n_ssm_heads
            # in_proj -> [z, x, B, C, dt]; conv over (x,B,C); out_proj
            conv_dim = di + 2 * N * 0 + 2 * self.ssm_state * H // H  # see mamba2.py
            mamba = d * (2 * di + 2 * self.ssm_state + H) + di * d + 4 * di
            n += self.n_layers * (mamba + 2 * d)
            n_shared = (attn + dense_mlp + 2 * d) if self.shared_attn_every else 0
            n += n_shared  # weight-tied: counted once
        elif self.family == "ssm":  # xlstm
            di = self.ssm_expand * d
            mlstm = d * (3 * di + di) + di * d + 3 * di
            n += self.n_layers * (mlstm + 2 * d)
        elif self.family == "audio":
            enc = self.encoder_layers * (attn + dense_mlp + 2 * d)
            dec = self.n_layers * (2 * attn + dense_mlp + 3 * d)
            n += enc + dec
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        attn = d * (self.n_heads * self.hd) + 2 * d * (self.n_kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        active_mlp = self.experts_per_token * 3 * d * self.d_ff + d * self.n_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * (attn + active_mlp + 2 * d)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

# Archs with sub-quadratic attention state that run long_500k (DESIGN.md §4).
LONG_CONTEXT_ARCHS = {"zamba2-7b", "xlstm-1.3b", "gemma3-12b"}


def shapes_for(arch_id: str) -> tuple[ShapeSpec, ...]:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch_id in LONG_CONTEXT_ARCHS:
        out.append(LONG_500K)
    return tuple(out)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build the SMOKE config: same family/topology, tiny sizes."""
    base = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=cfg.d_ff and 256,
        vocab_size=512,
        dtype="float32",
    )
    if cfg.family == "moe":
        base.update(n_experts=8, experts_per_token=2, d_ff=64)
    if cfg.family in ("hybrid", "ssm"):
        base.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
    if cfg.shared_attn_every:
        base.update(n_layers=4, shared_attn_every=2)
    if cfg.slstm_period:
        base.update(n_layers=4, slstm_period=2)
    if cfg.encoder_layers:
        base.update(encoder_layers=2, n_layers=2, encoder_seq=64)
    if cfg.n_patches:
        base.update(n_patches=16)
    if cfg.local_global_ratio:
        base.update(n_layers=6, local_global_ratio=2, sliding_window=32)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
