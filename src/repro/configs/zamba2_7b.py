"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone with a weight-shared
attention+MLP block applied every 6 mamba layers."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
)
SMOKE = reduced(CONFIG)
