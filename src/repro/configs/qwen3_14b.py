"""Qwen3-14B [hf:Qwen/Qwen3 family]: dense GQA with qk-norm."""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    rope_theta=1e6,
    qk_norm=True,
)
SMOKE = reduced(CONFIG)
