"""Critical-path profiler: attribute campaign makespan to phases.

Given a finished campaign's trace, walk the span DAG *backwards* from the
last thing that happened to the first submission, and charge every second
of wall time on that path to exactly one bucket — the generalized form of
the paper's "deploy vs stage vs compute" breakdown.

The walk: start at the job whose span ends last, at that instant. Move
backwards through the current job's phase spans, charging each to its
phase. When the cursor enters a QUEUED span, consult the recorder's
grant-causality edges: if the grant that ended this wait was *enabled by*
another job's release at the same instant, the path jumps to that job —
its activity, not abstract "queue wait", is what the makespan was spent
on. Waits with no recorded enabler (campaign-start contention, arrivals)
stay charged to ``queue_wait``. Time before the path-origin job's first
span is its ``arrival`` lead-in; any gap the trace cannot explain is
``unattributed`` rather than silently absorbed.

Buckets are disjoint and tile ``[t_start, t_end]`` exactly, so
``sum(phase_s.values()) == makespan_s`` by construction — the invariant
``examples/trace_campaign.py`` and the tests assert.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

#: Phase keys a critical path may contain, in display order.
PHASES = (
    "arrival",
    "queue_wait",
    "allocated",
    "provisioning",
    "staging_in",
    "running",
    "staging_out",
    "teardown",
    "unattributed",
)

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class PathSegment:
    """One stretch of the critical path charged to one job's phase."""

    job_id: Optional[int]
    name: Optional[str]
    phase: str
    t0: float
    t1: float

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass(frozen=True)
class CriticalPath:
    """Makespan attribution: ``phase_s`` tiles ``[t_start, t_end]``."""

    t_start: float
    t_end: float
    phase_s: dict[str, float]
    segments: tuple[PathSegment, ...]

    @property
    def makespan_s(self) -> float:
        return self.t_end - self.t_start

    def fraction(self, phase: str) -> float:
        span = self.makespan_s
        return self.phase_s.get(phase, 0.0) / span if span > 0 else 0.0


def _grant_cause(trace, job_id: int, t: float) -> Optional[int]:
    """The job whose release enabled ``job_id``'s grant at instant ``t``."""
    for gt, cause in reversed(trace.grant_causes.get(job_id, ())):
        if abs(gt - t) <= _EPS:
            return cause
        if gt < t - _EPS:
            break
    return None


def critical_path(trace) -> Optional[CriticalPath]:
    """Walk the span DAG of a finished campaign; ``None`` if the trace is
    empty. ``trace`` is a :class:`~repro.obs.trace.TraceRecorder` (or
    anything exposing ``spans`` / ``job_meta`` / ``grant_causes`` /
    ``t_range()``)."""
    spans = {j: s for j, s in trace.spans.items() if s}
    if not spans:
        return None
    t_start, t_end = trace.t_range()
    if t_end - t_start <= 0:
        return CriticalPath(t_start, t_end, {}, ())

    # path origin: the job whose last span ends last (ties: lowest id,
    # deterministic across runs)
    cur = min(spans, key=lambda j: (-spans[j][-1][2], j))
    cursor = t_end
    segments: list[PathSegment] = []
    jumped: set[tuple[int, float]] = set()

    def charge(job_id: Optional[int], phase: str, a: float, b: float) -> None:
        if b - a <= _EPS:
            return
        name = trace.job_meta.get(job_id, {}).get("name") if job_id is not None else None
        segments.append(PathSegment(job_id, name, phase, a, b))

    max_steps = 4 * sum(len(s) for s in spans.values()) + 16
    steps = 0
    while cursor > t_start + _EPS:
        steps += 1
        if steps > max_steps:                      # pathological trace: bail
            charge(None, "unattributed", t_start, cursor)
            cursor = t_start
            break
        job_spans = spans[cur]
        # rightmost span of the current job starting strictly before cursor
        idx = None
        for i in range(len(job_spans) - 1, -1, -1):
            if job_spans[i][1] < cursor - _EPS:
                idx = i
                break
        if idx is None:
            # before this job's first activity: arrival lead-in
            charge(cur, "arrival", t_start, cursor)
            cursor = t_start
            break
        phase, a, b = job_spans[idx]
        hi = min(b, cursor)
        if hi < cursor - _EPS:
            # nothing of this job (or its causes) covers (hi, cursor)
            charge(None, "unattributed", hi, cursor)
            cursor = hi
        if phase == "queued":
            cause = _grant_cause(trace, cur, hi)
            key = (cur, hi)
            if (
                cause is not None
                and cause in spans
                and key not in jumped
            ):
                # the wait ended because `cause` released: follow it
                jumped.add(key)
                cur = cause
                cursor = hi
                continue
            charge(cur, "queue_wait", a, hi)
        else:
            charge(cur, phase if phase in PHASES else "unattributed", a, hi)
        cursor = a

    segments.reverse()
    phase_s = {}
    for seg in segments:
        phase_s[seg.phase] = phase_s.get(seg.phase, 0.0) + seg.dur_s
    # float drift from summing many segments: pin the tiling invariant by
    # folding the residue into the largest bucket
    residue = (t_end - t_start) - sum(phase_s.values())
    if phase_s and abs(residue) > 0:
        top = max(phase_s, key=lambda k: phase_s[k])
        phase_s[top] += residue
    return CriticalPath(t_start, t_end, phase_s, tuple(segments))


def format_critical_path(cp: CriticalPath, *, max_segments: int = 0) -> str:
    """Human-readable breakdown; ``max_segments`` > 0 also lists the
    longest individual path segments."""
    lines = [
        f"critical path: {cp.makespan_s:.1f}s "
        f"({cp.t_start:.1f}s -> {cp.t_end:.1f}s), "
        f"{len(cp.segments)} segments"
    ]
    for phase in PHASES:
        s = cp.phase_s.get(phase, 0.0)
        if s <= 0:
            continue
        lines.append(f"  {phase:<14} {s:>12.1f}s  {100 * cp.fraction(phase):5.1f}%")
    if max_segments > 0:
        longest = sorted(cp.segments, key=lambda s: -s.dur_s)[:max_segments]
        lines.append("  longest segments:")
        for seg in longest:
            who = seg.name if seg.name is not None else "-"
            lines.append(
                f"    {seg.phase:<14} {seg.dur_s:>10.1f}s  "
                f"[{seg.t0:.1f}, {seg.t1:.1f}]  {who}"
            )
    return "\n".join(lines)
