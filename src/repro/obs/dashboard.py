"""Campaign dashboard: one self-contained static HTML report, zero deps.

:func:`write_dashboard` renders everything the observability layer knows
about one campaign — stat tiles, the critical-path breakdown, SLO /
error-budget accounting, the alert timeline, sparklines for every hub
series, and the campaign doctor's advisories — into a **single HTML file
with no external requests**: styles inline, charts as inline SVG, no
scripts, no fonts, no network. Open it from disk, attach it to a CI run,
mail it around; it renders the same everywhere, honors the viewer's
light/dark preference via ``prefers-color-scheme`` (with a ``data-theme``
override), and degrades to readable tables when SVG is unavailable.

:func:`format_dashboard` is the same report for a terminal: it composes
the section formatters (:func:`~repro.obs.profile.format_critical_path`,
:func:`~repro.obs.slo.format_slo_report`,
:func:`~repro.obs.alerts.format_alerts`,
:func:`~repro.obs.diagnose.format_advisories`) under one header.

Both entry points auto-derive what they are not handed: metrics from
``trace.metrics``, the alert engine from ``trace.alerts``, the SLO report
from the engine's tracker, advisories from :func:`~repro.obs.diagnose.diagnose`.

Cold-side module: hot loops never import this (``tools/check_obs_imports``).
"""

from __future__ import annotations

import html as _html
from typing import Optional

from .diagnose import diagnose, format_advisories
from .profile import PHASES, critical_path, format_critical_path

__all__ = ["build_dashboard", "write_dashboard", "format_dashboard"]

#: Max points per sparkline path (deterministic even-stride down-sample).
_SPARK_POINTS = 240

# Categorical slots (fixed order, never cycled) and chrome, light/dark —
# the reference palette instance from the dataviz method; phases and
# severities map to fixed slots so color follows the entity, never rank.
_SERIES_LIGHT = ("#2a78d6", "#eb6834", "#1baf7a", "#eda100",
                 "#e87ba4", "#008300", "#4a3aa7", "#e34948")
_SERIES_DARK = ("#3987e5", "#d95926", "#199e70", "#c98500",
                "#d55181", "#008300", "#9085e9", "#e66767")
#: Status palette (fixed, never themed; icon + label always ride along).
_STATUS = {"good": "#0ca30c", "warning": "#fab219",
           "serious": "#ec835a", "critical": "#d03b3b"}
_SEV_STATUS = {"info": "good", "warning": "warning", "critical": "critical"}

# Per-phase categorical assignment in PHASES display order; `unattributed`
# deliberately wears muted ink, not a series color — it is the "Other" bin.
_PHASE_SLOT = {p: i for i, p in enumerate(p for p in PHASES if p != "unattributed")}

_CSS = """
:root { color-scheme: light dark; }
body {
  margin: 0; padding: 24px;
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  background: var(--page); color: var(--ink);
  --page: #f9f9f7; --surface: #fcfcfb; --ink: #0b0b0b;
  --ink-2: #52514e; --muted: #898781; --grid: #e1e0d9;
  --baseline: #c3c2b7; --border: rgba(11,11,11,0.10);
  --s1: #2a78d6; --s2: #eb6834; --s3: #1baf7a; --s4: #eda100;
  --s5: #e87ba4; --s6: #008300; --s7: #4a3aa7; --s8: #e34948;
}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) body {
    color-scheme: dark;
    --page: #0d0d0d; --surface: #1a1a19; --ink: #ffffff;
    --ink-2: #c3c2b7; --muted: #898781; --grid: #2c2c2a;
    --baseline: #383835; --border: rgba(255,255,255,0.10);
    --s1: #3987e5; --s2: #d95926; --s3: #199e70; --s4: #c98500;
    --s5: #d55181; --s6: #008300; --s7: #9085e9; --s8: #e66767;
  }
}
:root[data-theme="dark"] body {
  color-scheme: dark;
  --page: #0d0d0d; --surface: #1a1a19; --ink: #ffffff;
  --ink-2: #c3c2b7; --muted: #898781; --grid: #2c2c2a;
  --baseline: #383835; --border: rgba(255,255,255,0.10);
  --s1: #3987e5; --s2: #d95926; --s3: #199e70; --s4: #c98500;
  --s5: #d55181; --s6: #008300; --s7: #9085e9; --s8: #e66767;
}
h1 { font-size: 20px; margin: 0 0 4px; }
h2 { font-size: 14px; margin: 28px 0 10px; color: var(--ink-2);
     text-transform: uppercase; letter-spacing: 0.06em; }
.sub { color: var(--muted); font-size: 12px; margin-bottom: 20px; }
.card { background: var(--surface); border: 1px solid var(--border);
        border-radius: 8px; padding: 14px 16px; }
.tiles { display: flex; flex-wrap: wrap; gap: 12px; }
.tile { background: var(--surface); border: 1px solid var(--border);
        border-radius: 8px; padding: 10px 16px; min-width: 110px; }
.tile .v { font-size: 22px; font-weight: 600; }
.tile .k { font-size: 11px; color: var(--muted); margin-top: 2px; }
table { border-collapse: collapse; width: 100%; font-size: 13px; }
th { text-align: left; color: var(--muted); font-weight: 500;
     font-size: 11px; padding: 4px 10px 4px 0;
     border-bottom: 1px solid var(--grid); }
td { padding: 5px 10px 5px 0; border-bottom: 1px solid var(--grid);
     font-variant-numeric: tabular-nums; }
.chip { display: inline-block; font-size: 11px; padding: 1px 8px;
        border-radius: 9px; border: 1px solid var(--border); }
.chip .dot { display: inline-block; width: 8px; height: 8px;
             border-radius: 4px; margin-right: 5px; }
.legend { display: flex; flex-wrap: wrap; gap: 6px 14px;
          font-size: 12px; color: var(--ink-2); margin-top: 8px; }
.legend .sw { display: inline-block; width: 10px; height: 10px;
              border-radius: 3px; margin-right: 5px; vertical-align: -1px; }
.sparks { display: grid; gap: 12px;
          grid-template-columns: repeat(auto-fill, minmax(280px, 1fr)); }
.spark .name { font-size: 12px; color: var(--ink-2); }
.spark .now { font-size: 13px; font-weight: 600; float: right; }
.adv { margin: 10px 0; padding: 10px 14px; border-left: 3px solid var(--muted);
       background: var(--surface); border-radius: 0 8px 8px 0; }
.adv .head { font-weight: 600; font-size: 13px; }
.adv .rec { color: var(--ink-2); font-size: 13px; margin-top: 3px; }
.adv .why { color: var(--muted); font-size: 12px; margin-top: 3px; }
.none { color: var(--muted); font-size: 13px; }
svg text { font-family: inherit; }
"""


def _esc(v) -> str:
    return _html.escape(str(v), quote=True)


def _fmt_s(s: float) -> str:
    if s >= 3600:
        return f"{s / 3600:,.1f}h"
    if s >= 60:
        return f"{s / 60:,.1f}m"
    return f"{s:,.1f}s"


def _downsample(pts, cap):
    n = len(pts)
    if n <= cap:
        return pts
    idx = sorted({round(i * (n - 1) / (cap - 1)) for i in range(cap)})
    return [pts[i] for i in idx]


def _phase_color(phase: str) -> str:
    slot = _PHASE_SLOT.get(phase)
    return "var(--muted)" if slot is None else f"var(--s{slot % 8 + 1})"


def _tiles(items) -> str:
    cells = "".join(
        f'<div class="tile"><div class="v">{_esc(v)}</div>'
        f'<div class="k">{_esc(k)}</div></div>'
        for k, v in items
    )
    return f'<div class="tiles">{cells}</div>'


# -- sections -----------------------------------------------------------------

def _section_critical_path(cp) -> str:
    if cp is None or cp.makespan_s <= 0:
        return '<p class="none">no spans recorded</p>'
    present = [p for p in PHASES if cp.phase_s.get(p, 0.0) > 0]
    w, h = 720.0, 34.0
    x = 0.0
    segs = []
    for p in present:
        frac = cp.fraction(p)
        sw = max(0.0, frac * w - 2.0)            # 2px surface gap between fills
        segs.append(
            f'<rect x="{x:.1f}" y="4" width="{sw:.1f}" height="22" rx="3" '
            f'fill="{_phase_color(p)}"><title>{_esc(p)}: '
            f'{_fmt_s(cp.phase_s[p])} ({frac:.1%})</title></rect>'
        )
        x += frac * w
    legend = "".join(
        f'<span><span class="sw" style="background:{_phase_color(p)}"></span>'
        f"{_esc(p)} {cp.fraction(p):.0%}</span>"
        for p in present
    )
    return (
        f'<div class="card"><svg viewBox="0 0 {w:g} {h:g}" role="img" '
        f'aria-label="critical path by phase" width="100%" height="{h:g}">'
        f'{"".join(segs)}</svg>'
        f'<div class="legend">{legend}</div>'
        f'<div class="sub" style="margin:6px 0 0">makespan '
        f"{_fmt_s(cp.makespan_s)} across {len(cp.segments)} path segments; "
        "buckets tile the makespan exactly</div></div>"
    )


def _chip(status: str, label: str) -> str:
    color = _STATUS[status]
    icon = {"good": "&#10003;", "warning": "&#9888;",
            "serious": "&#9888;", "critical": "&#10007;"}[status]
    return (
        f'<span class="chip"><span class="dot" '
        f'style="background:{color}"></span>{icon} {_esc(label)}</span>'
    )


def _section_slos(slo) -> str:
    if slo is None or not slo.statuses:
        return '<p class="none">no SLOs defined</p>'
    rows = []
    for s in slo.statuses:
        burns = "  ".join(f"{w}s: {r:.2f}" for w, r in s.burn_rates.items())
        # budget bar: share spent, clamped; state colors carry icon+label
        spent = min(1.0, max(0.0, s.budget_consumed))
        state = "critical" if s.breached else ("warning" if spent > 0.5 else "good")
        bar = (
            '<svg width="120" height="10" viewBox="0 0 120 10">'
            '<rect x="0" y="2" width="120" height="6" rx="3" fill="var(--grid)"/>'
            f'<rect x="0" y="2" width="{120 * spent:.1f}" height="6" rx="3" '
            f'fill="{_STATUS[state]}"><title>error budget '
            f"{s.budget_consumed:.0%} spent</title></rect></svg>"
        )
        rows.append(
            "<tr>"
            f"<td>{_esc(s.name)}</td>"
            f"<td>{_esc(s.objective_desc)}</td>"
            f"<td>{s.attainment:.2%} <span class=\"sub\">({s.n_bad}/"
            f"{s.n_samples} bad)</span></td>"
            f"<td>{bar}</td>"
            f"<td>{_esc(burns)}</td>"
            f"<td>{_chip(state, 'breached' if s.breached else 'ok')}</td>"
            "</tr>"
        )
    return (
        '<div class="card"><table><thead><tr>'
        "<th>SLO</th><th>objective</th><th>attainment</th>"
        "<th>error budget spent</th><th>burn rates</th><th>state</th>"
        f'</tr></thead><tbody>{"".join(rows)}</tbody></table></div>'
    )


def _node_outages(events, t_end: float):
    """Pair ``node_down``/``node_repair`` trace events into per-node outage
    windows; an unrepaired node's window runs to the end of the trace."""
    open_: dict[str, float] = {}
    out = []
    for kind, t, _label, args in events:
        if kind == "node_down":
            open_.setdefault(args["node_id"], t)
        elif kind == "node_repair":
            t_down = open_.pop(args["node_id"], None)
            if t_down is not None:
                out.append((args["node_id"], t_down, t))
    for nid, t_down in open_.items():
        out.append((nid, t_down, max(t_end, t_down)))
    out.sort(key=lambda o: (o[1], o[0]))
    return out


def _section_alerts(engine, t0: float, t1: float, outages=()) -> str:
    rules = list(engine.rules) if engine is not None else []
    if not rules and not outages:
        return '<p class="none">no alert rules registered</p>'
    span = max(t1 - t0, 1e-9)
    w, row_h, label_w = 720.0, 22.0, 170.0
    rows, marks = [], []
    for i, rule in enumerate(rules):
        y = i * row_h
        sev = _SEV_STATUS.get(rule.severity, "warning")
        rows.append(
            f'<text x="0" y="{y + 15:.1f}" font-size="12" '
            f'fill="var(--ink-2)">{_esc(rule.name)}</text>'
        )
        marks.append(
            f'<line x1="{label_w}" y1="{y + 11:.1f}" x2="{w}" '
            f'y2="{y + 11:.1f}" stroke="var(--grid)" stroke-width="1"/>'
        )
        for inc in engine.incidents_for(rule.name):
            a = label_w + (inc.t_fired - t0) / span * (w - label_w)
            end_t = inc.t_resolved if inc.t_resolved is not None else t1
            b = label_w + (end_t - t0) / span * (w - label_w)
            state = "fired, still open" if inc.open else "resolved"
            marks.append(
                f'<rect x="{a:.1f}" y="{y + 5:.1f}" '
                f'width="{max(3.0, b - a):.1f}" height="12" rx="3" '
                f'fill="{_STATUS[sev]}" stroke="var(--surface)" '
                f'stroke-width="2"><title>{_esc(rule.name)} '
                f"[{_esc(rule.severity)}] fired {_fmt_s(inc.t_fired)} "
                f"({state})</title></rect>"
            )
    if outages:
        y = len(rows) * row_h
        rows.append(
            f'<text x="0" y="{y + 15:.1f}" font-size="12" '
            f'fill="var(--ink-2)">node outages</text>'
        )
        marks.append(
            f'<line x1="{label_w}" y1="{y + 11:.1f}" x2="{w}" '
            f'y2="{y + 11:.1f}" stroke="var(--grid)" stroke-width="1"/>'
        )
        for nid, t_down, t_up in outages:
            a = label_w + (t_down - t0) / span * (w - label_w)
            b = label_w + (t_up - t0) / span * (w - label_w)
            marks.append(
                f'<rect x="{a:.1f}" y="{y + 5:.1f}" '
                f'width="{max(3.0, b - a):.1f}" height="12" rx="3" '
                f'fill="{_STATUS["critical"]}" stroke="var(--surface)" '
                f'stroke-width="2"><title>{_esc(nid)} down '
                f"{_fmt_s(t_down)} &#8594; {_fmt_s(t_up)}</title></rect>"
            )
    h = len(rows) * row_h + 4
    parts = []
    if engine is not None:
        parts.append(
            f"{len(engine.incidents)} incident(s), "
            f"{engine.pending_cancelled} flap(s) suppressed by hysteresis, "
            f"{engine.evaluations} evaluations on the virtual clock"
        )
    if outages:
        parts.append(f"{len(outages)} storage-node outage window(s)")
    summary = "; ".join(parts)
    legend = "".join(
        f'<span><span class="sw" style="background:{_STATUS[s]}"></span>'
        f"{lbl}</span>"
        for lbl, s in (("info", "good"), ("warning", "warning"),
                       ("critical", "critical"))
    )
    return (
        f'<div class="card"><svg viewBox="0 0 {w:g} {h:g}" width="100%" '
        f'height="{h:g}" role="img" aria-label="alert incident timeline">'
        f'{"".join(marks)}{"".join(rows)}</svg>'
        f'<div class="legend">{legend}</div>'
        f'<div class="sub" style="margin:6px 0 0">{_esc(summary)}</div></div>'
    )


def _spark(name: str, series) -> str:
    pts = _downsample(series.items(), _SPARK_POINTS)
    w, h, pad = 280.0, 56.0, 4.0
    if len(pts) < 2:
        body = (
            f'<text x="{w / 2}" y="{h / 2}" text-anchor="middle" '
            f'font-size="11" fill="var(--muted)">not enough samples</text>'
        )
        now = "" if not pts else f"{pts[-1][1]:g}"
    else:
        ts = [t for t, _ in pts]
        vs = [v for _, v in pts]
        t0, t1 = ts[0], ts[-1]
        lo, hi = min(vs), max(vs)
        tspan = (t1 - t0) or 1.0
        vspan = (hi - lo) or 1.0
        xy = [
            (
                pad + (t - t0) / tspan * (w - 2 * pad),
                h - pad - (v - lo) / vspan * (h - 2 * pad),
            )
            for t, v in pts
        ]
        line = " ".join(f"{x:.1f},{y:.1f}" for x, y in xy)
        area = (
            f"{xy[0][0]:.1f},{h - pad:.1f} " + line
            + f" {xy[-1][0]:.1f},{h - pad:.1f}"
        )
        lx, ly = xy[-1]
        body = (
            f'<line x1="{pad}" y1="{h - pad}" x2="{w - pad}" y2="{h - pad}" '
            'stroke="var(--baseline)" stroke-width="1"/>'
            f'<polygon points="{area}" fill="var(--s1)" opacity="0.10"/>'
            f'<polyline points="{line}" fill="none" stroke="var(--s1)" '
            'stroke-width="2" stroke-linejoin="round"/>'
            f'<circle cx="{lx:.1f}" cy="{ly:.1f}" r="4" fill="var(--s1)" '
            'stroke="var(--surface)" stroke-width="2">'
            f"<title>{_esc(name)}: {vs[-1]:g} at {_fmt_s(t1)}</title></circle>"
        )
        now = f"{vs[-1]:g}"
    truncated = " (ring truncated)" if series.appended > len(series) else ""
    return (
        '<div class="card spark">'
        f'<span class="now">{_esc(now)}</span>'
        f'<div class="name">{_esc(name)}{truncated}</div>'
        f'<svg viewBox="0 0 {w:g} {h:g}" width="100%" height="{h:g}" '
        f'role="img" aria-label="{_esc(name)} over virtual time">{body}</svg>'
        "</div>"
    )


def _section_series(hub) -> str:
    if hub is None or not hub.series:
        return '<p class="none">no metric series recorded</p>'
    sparks = "".join(
        _spark(name, hub.series[name]) for name in sorted(hub.series)
    )
    return f'<div class="sparks">{sparks}</div>'


def _section_advisories(advisories) -> str:
    if not advisories:
        return '<p class="none">campaign doctor: nothing to flag</p>'
    out = []
    for i, a in enumerate(advisories, 1):
        sev = ("critical" if a.severity >= 0.6
               else "serious" if a.severity >= 0.4 else "warning")
        out.append(
            f'<div class="adv" style="border-left-color:{_STATUS[sev]}">'
            f'<div class="head">{i}. {_esc(a.code)} '
            f"{_chip(sev, f'severity {a.severity:.2f}')}</div>"
            f'<div class="rec">{_esc(a.summary)}</div>'
            f'<div class="rec">&#8594; {_esc(a.recommendation)}</div>'
            f'<div class="why">evidence: {_esc(a.evidence)}</div></div>'
        )
    return "".join(out)


# -- entry points -------------------------------------------------------------

def build_dashboard(
    trace,
    *,
    metrics=None,
    slo=None,
    alerts=None,
    advisories=None,
    report=None,
    title: str = "Campaign observability report",
) -> str:
    """Render the HTML report and return it as a string.

    Everything except ``trace`` is optional and auto-derived when omitted:
    ``metrics`` from ``trace.metrics``, ``alerts`` from ``trace.alerts``,
    ``slo`` from the engine's tracker, ``advisories`` from
    :func:`~repro.obs.diagnose.diagnose`.
    """
    if metrics is None:
        metrics = getattr(trace, "metrics", None)
    if alerts is None:
        alerts = getattr(trace, "alerts", None)
    if slo is None and alerts is not None and alerts.slos is not None:
        slo = alerts.slos.report()
    if advisories is None:
        advisories = diagnose(trace, metrics=metrics, report=report, slos=slo)
    trace._materialize()
    cp = critical_path(trace)
    t0, t1 = trace.t_range() if trace.spans else (0.0, 0.0)
    outages = _node_outages(trace.events, t1)
    if outages:
        t1 = max(t1, max(o[2] for o in outages))

    n_jobs = len(trace.spans)
    n_events = len(trace.events)
    n_fired = 0 if alerts is None else len(alerts.incidents)
    n_breached = 0 if slo is None else len(slo.breached)
    tiles = _tiles(
        [
            ("jobs traced", f"{n_jobs:,}"),
            ("makespan", _fmt_s(t1 - t0)),
            ("trace events", f"{n_events:,}"),
            ("alerts fired", f"{n_fired:,}"),
            ("SLOs breached", f"{n_breached:,}"),
            ("advisories", f"{len(advisories):,}"),
        ]
    )
    return (
        "<!DOCTYPE html>\n"
        '<html lang="en"><head><meta charset="utf-8">\n'
        f"<title>{_esc(title)}</title>\n"
        f"<style>{_CSS}</style>\n"
        "</head><body>\n"
        f"<h1>{_esc(title)}</h1>\n"
        f'<div class="sub">virtual time {_fmt_s(t0)} &#8594; {_fmt_s(t1)} '
        "&#183; self-contained report, no external requests</div>\n"
        f"{tiles}\n"
        f"<h2>Campaign doctor</h2>\n{_section_advisories(advisories)}\n"
        f"<h2>Critical path</h2>\n{_section_critical_path(cp)}\n"
        f"<h2>SLOs &amp; error budgets</h2>\n{_section_slos(slo)}\n"
        f"<h2>Alert timeline</h2>\n{_section_alerts(alerts, t0, t1, outages)}\n"
        f"<h2>Metric series</h2>\n{_section_series(metrics)}\n"
        "</body></html>\n"
    )


def write_dashboard(path, trace, **kwargs) -> str:
    """Write :func:`build_dashboard` output to ``path``; returns the path."""
    doc = build_dashboard(trace, **kwargs)
    with open(path, "w", encoding="utf-8") as f:
        f.write(doc)
    return str(path)


def format_dashboard(
    trace, *, metrics=None, slo=None, alerts=None, advisories=None, report=None
) -> str:
    """The same report, composed for a terminal."""
    if metrics is None:
        metrics = getattr(trace, "metrics", None)
    if alerts is None:
        alerts = getattr(trace, "alerts", None)
    if slo is None and alerts is not None and alerts.slos is not None:
        slo = alerts.slos.report()
    if advisories is None:
        advisories = diagnose(trace, metrics=metrics, report=report, slos=slo)
    trace._materialize()
    cp = critical_path(trace)
    t0, t1 = trace.t_range() if trace.spans else (0.0, 0.0)
    parts = [
        f"campaign observability report  ({len(trace.spans)} jobs, "
        f"virtual {_fmt_s(t0)} -> {_fmt_s(t1)})",
        format_advisories(advisories),
    ]
    if cp is not None:
        parts.append(format_critical_path(cp))
    if slo is not None:
        from .slo import format_slo_report

        parts.append(format_slo_report(slo))
    if alerts is not None:
        from .alerts import format_alerts

        parts.append(format_alerts(alerts))
    return "\n\n".join(parts)
