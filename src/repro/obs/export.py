"""Trace exporters: Chrome trace-event JSON (Perfetto) and JSONL.

The Chrome export lays a campaign out on four process tracks:

* ``jobs`` — one thread per job, ``X`` (complete) spans per lifecycle
  phase, ``i`` instants for terminal DONE/FAILED markers and faults, and
  ``s``/``f`` flow arrows from each fault/preemption to the grant of the
  requeued attempt it caused (the parent → resume causal link).
* ``storage sessions`` — one thread per negotiated backend, a span per
  granted session (grant → release), plus negotiation instants carrying
  per-backend rejection reasons.
* ``storage pools`` — one thread per pool: its lifetime span
  (create → teardown, or trace end while still live), lease
  attach/release instants, and eviction instants.
* ``metrics`` — every :class:`~repro.obs.metrics.MetricsHub` time series
  as Chrome ``C`` counter events (rendered as area charts).

Timestamps are virtual seconds scaled to microseconds (the unit the
trace-event format mandates). Load the file at https://ui.perfetto.dev
or ``chrome://tracing``.

The JSONL export is the programmatic twin: one self-describing record per
line (``span`` / ``session`` / ``event`` / ``count``), for pandas-style
analysis without a trace viewer.
"""

from __future__ import annotations

import json
from typing import Iterator, Optional

_PID_JOBS = 1
_PID_SESSIONS = 2
_PID_POOLS = 3
_PID_METRICS = 4

#: Stable colors per phase (Chrome trace color names).
_PHASE_COLOR = {
    "queued": "grey",
    "allocated": "thread_state_runnable",
    "provisioning": "thread_state_iowait",
    "staging_in": "rail_load",
    "running": "thread_state_running",
    "staging_out": "rail_response",
    "teardown": "terrible",
}


def _us(t: float) -> float:
    return round(t * 1e6, 3)


def _meta(pid: int, tid: int, field: str, name: str) -> dict:
    return {
        "ph": "M",
        "pid": pid,
        "tid": tid,
        "name": field,
        "args": {"name": name},
    }


def chrome_trace(trace, metrics=None) -> dict:
    """Render a :class:`~repro.obs.trace.TraceRecorder` (and optional
    :class:`~repro.obs.metrics.MetricsHub`) as a trace-event JSON dict."""
    ev: list[dict] = []
    ev.append(_meta(_PID_JOBS, 0, "process_name", "jobs"))
    ev.append(_meta(_PID_SESSIONS, 0, "process_name", "storage sessions"))
    ev.append(_meta(_PID_POOLS, 0, "process_name", "storage pools"))
    ev.append(_meta(_PID_METRICS, 0, "process_name", "metrics"))

    _, t_end = trace.t_range()

    # -- job phase spans ------------------------------------------------------
    for jid in sorted(trace.spans):
        meta = trace.job_meta.get(jid, {})
        label = meta.get("name", f"job {jid}")
        ev.append(_meta(_PID_JOBS, jid, "thread_name", f"{label} #{jid}"))
        for phase, t0, t1 in trace.spans[jid]:
            if phase in ("done", "failed"):
                ev.append(
                    {
                        "ph": "i",
                        "pid": _PID_JOBS,
                        "tid": jid,
                        "ts": _us(t0),
                        "s": "t",
                        "name": phase,
                        "cat": "terminal",
                    }
                )
                continue
            span = {
                "ph": "X",
                "pid": _PID_JOBS,
                "tid": jid,
                "ts": _us(t0),
                "dur": _us(t1 - t0),
                "name": phase,
                "cat": "phase",
                "args": {"job_id": jid, "backend": meta.get("backend")},
            }
            color = _PHASE_COLOR.get(phase)
            if color is not None:
                span["cname"] = color
            ev.append(span)

    # -- requeue causal links: fault/preempt -> next grant of the same job ----
    grants_by_job: dict[int, list[float]] = {}
    for kind, t, _label, args in trace.events:
        if kind == "grant":
            grants_by_job.setdefault(args["job_id"], []).append(t)
    flow_id = 0
    for kind, t, label, args in trace.events:
        if kind not in ("fault", "preempt"):
            continue
        jid = args["job_id"]
        ev.append(
            {
                "ph": "i",
                "pid": _PID_JOBS,
                "tid": jid,
                "ts": _us(t),
                "s": "t",
                "name": kind,
                "cat": kind,
                "args": args,
            }
        )
        if kind == "fault" and not args.get("requeued"):
            continue
        nxt = next((g for g in grants_by_job.get(jid, ()) if g >= t), None)
        if nxt is None:
            continue
        flow_id += 1
        common = {"pid": _PID_JOBS, "tid": jid, "cat": "requeue", "id": flow_id}
        ev.append({"ph": "s", "ts": _us(t), "name": f"{kind} requeue", **common})
        ev.append(
            {
                "ph": "f",
                "ts": _us(nxt),
                "name": f"{kind} requeue",
                "bp": "e",
                **common,
            }
        )

    # -- per-backend session tracks ------------------------------------------
    backend_tid: dict[Optional[str], int] = {}

    def _btid(backend: Optional[str]) -> int:
        tid = backend_tid.get(backend)
        if tid is None:
            tid = backend_tid[backend] = len(backend_tid) + 1
            ev.append(
                _meta(_PID_SESSIONS, tid, "thread_name", str(backend or "unknown"))
            )
        return tid

    for jid, backend, pool_id, t0, t1 in trace.sessions:
        name = trace.job_meta.get(jid, {}).get("name", f"job {jid}")
        ev.append(
            {
                "ph": "X",
                "pid": _PID_SESSIONS,
                "tid": _btid(backend),
                "ts": _us(t0),
                "dur": _us(t1 - t0),
                "name": name,
                "cat": "session",
                "args": {"job_id": jid, "pool_id": pool_id},
            }
        )
    for kind, t, label, args in trace.events:
        if kind != "negotiation":
            continue
        ev.append(
            {
                "ph": "i",
                "pid": _PID_SESSIONS,
                "tid": _btid(args.get("backend")),
                "ts": _us(t),
                "s": "t",
                "name": f"negotiate {label}",
                "cat": "negotiation",
                "args": args,
            }
        )

    # -- per-pool tracks ------------------------------------------------------
    pool_open: dict[int, tuple[float, dict]] = {}
    pool_named: set[int] = set()

    def _pool_track(pool_id: int) -> int:
        if pool_id not in pool_named:
            pool_named.add(pool_id)
            ev.append(_meta(_PID_POOLS, pool_id, "thread_name", f"pool {pool_id}"))
        return pool_id

    for kind, t, label, args in trace.events:
        pid = args.get("pool_id")
        if pid is None:
            continue
        if kind == "pool_created":
            pool_open[pid] = (t, args)
            _pool_track(pid)
        elif kind == "pool_torn_down":
            opened = pool_open.pop(pid, (t, {}))
            ev.append(
                {
                    "ph": "X",
                    "pid": _PID_POOLS,
                    "tid": _pool_track(pid),
                    "ts": _us(opened[0]),
                    "dur": _us(t - opened[0]),
                    "name": f"pool {pid}",
                    "cat": "pool",
                    "args": opened[1],
                }
            )
        elif kind in ("lease_attached", "lease_released", "eviction", "pool_retired"):
            ev.append(
                {
                    "ph": "i",
                    "pid": _PID_POOLS,
                    "tid": _pool_track(pid),
                    "ts": _us(t),
                    "s": "t",
                    "name": f"{kind} {label}",
                    "cat": kind,
                    "args": args,
                }
            )
    for pid, (t0, args) in pool_open.items():   # still live at trace end
        ev.append(
            {
                "ph": "X",
                "pid": _PID_POOLS,
                "tid": _pool_track(pid),
                "ts": _us(t0),
                "dur": _us(max(t_end, t0) - t0),
                "name": f"pool {pid} (live)",
                "cat": "pool",
                "args": args,
            }
        )

    # -- metrics counter tracks ----------------------------------------------
    if metrics is None:
        metrics = getattr(trace, "metrics", None)
    if metrics is not None:
        for name, series in metrics.series.items():
            for t, v in series.items():
                ev.append(
                    {
                        "ph": "C",
                        "pid": _PID_METRICS,
                        "tid": 0,
                        "ts": _us(t),
                        "name": name,
                        "args": {name: v},
                    }
                )

    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_chrome_trace(path, trace, metrics=None) -> dict:
    """Serialize :func:`chrome_trace` to ``path``; returns the dict."""
    doc = chrome_trace(trace, metrics)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def jsonl_records(trace) -> Iterator[dict]:
    """Flat self-describing records for programmatic analysis."""
    for jid in sorted(trace.spans):
        meta = trace.job_meta.get(jid, {})
        for phase, t0, t1 in trace.spans[jid]:
            yield {
                "type": "span",
                "job_id": jid,
                "name": meta.get("name"),
                "phase": phase,
                "t0": t0,
                "t1": t1,
                "dur_s": t1 - t0,
            }
    for jid, backend, pool_id, t0, t1 in trace.sessions:
        yield {
            "type": "session",
            "job_id": jid,
            "backend": backend,
            "pool_id": pool_id,
            "t0": t0,
            "t1": t1,
        }
    for kind, t, label, args in trace.events:
        yield {"type": "event", "kind": kind, "t": t, "label": label, **args}
    for key, n in sorted(trace.counts.items()):
        yield {"type": "count", "key": key, "n": n}


def write_jsonl(path, trace) -> int:
    """Write one JSON record per line; returns the record count."""
    n = 0
    with open(path, "w") as f:
        for rec in jsonl_records(trace):
            f.write(json.dumps(rec))
            f.write("\n")
            n += 1
    return n
