"""Campaign doctor: cross-reference the trace into ranked advisories.

:func:`diagnose` reads a finished (or mid-flight) campaign's evidence —
the critical-path phase buckets, the metrics series, negotiation
rejections, eviction/preemption counters, reservation events — and emits
:class:`Advisory` records ranked by severity: what the campaign was
actually bound by, with the numbers that prove it and the knob to turn.

The checks (each fires only when its evidence clears a threshold):

* **stage_in_bound** — staging-in dominates the critical path; pairs the
  fraction with the pool hit rate ("stage-in bound: 61% of makespan, pool
  hit-rate 12% — grow the pool / route with DataAwarePolicy").
* **provisioning_bound** — per-job deploy/teardown dominates; pooled
  lease-attach skips it.
* **head_blocking** — queue wait dominates and one wide job's active span
  overlaps most of everyone else's queued time (found with an
  interval-sweep integral, not an O(jobs²) scan): the scheduler is
  head-blocked behind it; backfill / EASY reservations are the knob.
* **pool_thrash** — the same datasets get evicted and re-staged over and
  over: the pool is too small for the working set.
* **fault_churn** — requeued faults are eating the campaign; checkpoints
  bound the replay cost.
* **node_churn** — storage nodes died mid-campaign: how many attempts ran
  degraded or refaulted, and whether pools healed by backfill or repair.
* **negotiation_pressure** — specs failing negotiation outright, with the
  per-backend rejection reasons histogrammed.
* **slo_breach** — any SLO with its error budget overspent (when an
  :class:`~repro.obs.slo.SLOReport` is handed in).
* **serving_queue_bound** — serving campaigns (span-free traces with
  replica lifecycle events): the TTFT tail is queueing for a slot rather
  than prefill; capacity should arrive earlier.

Pure reporting: reads the recorder/hub, never the live engine. Cold-side
module — hot loops never import it (``tools/check_obs_imports``).
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Optional

from .profile import critical_path

__all__ = ["Advisory", "diagnose", "format_advisories"]

#: Lifecycle phases that count as "the job holds resources / is active".
_ACTIVE_PHASES = (
    "allocated",
    "provisioning",
    "staging_in",
    "running",
    "staging_out",
    "teardown",
)


@dataclasses.dataclass(frozen=True)
class Advisory:
    """One ranked, evidence-backed finding."""

    code: str
    severity: float            # ranking weight, roughly "fraction of campaign"
    summary: str
    recommendation: str
    evidence: dict

    def __str__(self) -> str:
        return f"[{self.code} {self.severity:.2f}] {self.summary}"


# -- helpers ------------------------------------------------------------------

def _hit_rate(trace, metrics, report) -> Optional[float]:
    """Dataset cache hit rate, from the report if present, else the probe."""
    pool = getattr(report, "pool", None) if report is not None else None
    if pool is not None:
        return pool.hit_rate
    if metrics is not None:
        s = metrics.series.get("catalog_hit_rate")
        if s is not None and len(s):
            return s.last()[1]
    return None


class _QueuedIntegral:
    """Step-function integral of queued-job concurrency over time.

    Built once from every queued span; ``between(a, b)`` returns total
    queued job-seconds inside ``[a, b]`` in O(log n).
    """

    def __init__(self, queued_spans):
        deltas: dict[float, int] = {}
        for t0, t1 in queued_spans:
            if t1 > t0:
                deltas[t0] = deltas.get(t0, 0) + 1
                deltas[t1] = deltas.get(t1, 0) - 1
        self.ts = sorted(deltas)
        self.counts = []           # concurrency on [ts[i], ts[i+1])
        self.cum = []              # integral from ts[0] to ts[i]
        level = 0
        acc = 0.0
        prev = None
        for t in self.ts:
            if prev is not None:
                acc += level * (t - prev)
            self.cum.append(acc)
            level += deltas[t]
            self.counts.append(level)
            prev = t

    def _at(self, t: float) -> float:
        """Integral from ts[0] to ``t`` (level past the last edge is 0)."""
        if not self.ts or t <= self.ts[0]:
            return 0.0
        i = bisect.bisect_right(self.ts, t) - 1
        return self.cum[i] + self.counts[i] * (t - self.ts[i])

    def between(self, a: float, b: float) -> float:
        if b <= a:
            return 0.0
        return self._at(b) - self._at(a)


# -- checks -------------------------------------------------------------------

def _check_stage_in_bound(cp, trace, metrics, report, churned) -> Optional[Advisory]:
    frac = cp.fraction("staging_in")
    if frac < 0.35:
        return None
    hit = _hit_rate(trace, metrics, report)
    hit_txt = f", pool hit-rate {hit:.0%}" if hit is not None else ""
    churn_txt = " (partly self-inflicted: see pool_thrash)" if churned else ""
    rec = (
        "grow the pool / working-set capacity so hot datasets stay resident"
        if hit is not None
        else "enable persistent pools so shared datasets stage once "
        "(Orchestrator.enable_pools + DataAwarePolicy)"
    )
    return Advisory(
        code="stage_in_bound",
        # churn makes the re-staging a symptom, not the root cause — rank
        # the thrash advisory above this one in that case
        severity=frac * (0.6 if churned else 1.0),
        summary=(
            f"stage-in bound: {frac:.0%} of the makespan's critical path is "
            f"staging data in{hit_txt}{churn_txt}"
        ),
        recommendation=rec,
        evidence={
            "staging_in_fraction": round(frac, 4),
            "staging_in_s": round(cp.phase_s.get("staging_in", 0.0), 1),
            "hit_rate": None if hit is None else round(hit, 4),
        },
    )


def _check_provisioning_bound(cp) -> Optional[Advisory]:
    frac = cp.fraction("provisioning") + cp.fraction("teardown")
    if frac < 0.25:
        return None
    return Advisory(
        code="provisioning_bound",
        severity=frac,
        summary=(
            f"provisioning bound: {frac:.0%} of the critical path is per-job "
            "filesystem deploy/teardown"
        ),
        recommendation=(
            "route jobs through POOLED storage specs: a lease attach skips "
            "the per-job deploy/teardown entirely"
        ),
        evidence={
            "provisioning_s": round(cp.phase_s.get("provisioning", 0.0), 1),
            "teardown_s": round(cp.phase_s.get("teardown", 0.0), 1),
            "fraction": round(frac, 4),
        },
    )


def _check_head_blocking(cp, trace) -> Optional[Advisory]:
    frac = cp.fraction("queue_wait")
    if frac < 0.30:
        return None
    spans = trace.spans
    queued = [
        (t0, t1)
        for s in spans.values()
        for phase, t0, t1 in s
        if phase == "queued" and t1 > t0
    ]
    if not queued:
        return None
    integral = _QueuedIntegral(queued)
    # width per job from its grants (compute + storage nodes actually held)
    width: dict[int, int] = {}
    for kind, _t, _label, args in trace.events:
        if kind == "grant":
            w = args.get("n_compute", 0) + args.get("n_storage", 0)
            jid = args["job_id"]
            if w > width.get(jid, 0):
                width[jid] = w
    best_jid, best_score, best_overlap = None, 0.0, 0.0
    for jid, s in spans.items():
        overlap = sum(
            integral.between(t0, t1)
            for phase, t0, t1 in s
            if phase in _ACTIVE_PHASES
        )
        score = overlap * max(1, width.get(jid, 1))
        if score > best_score or (score == best_score and best_jid is not None
                                  and jid < best_jid):
            best_jid, best_score, best_overlap = jid, score, overlap
    if best_jid is None or best_overlap <= 0:
        return None
    meta = trace.job_meta.get(best_jid, {})
    name = meta.get("name", f"job {best_jid}")
    n_res = sum(1 for e in trace.events if e[0] == "reservation")
    return Advisory(
        code="head_blocking",
        severity=frac,
        summary=(
            f"scheduler head-blocked: {frac:.0%} of the critical path is "
            f"queue wait, mostly behind {name!r} (#{best_jid}, width "
            f"{width.get(best_jid, 1)} nodes, {best_overlap:,.0f} queued "
            "job-seconds overlapped its run)"
        ),
        recommendation=(
            "let narrow jobs around the head: BackfillPolicy, or "
            "EasyBackfillPolicy for a no-starvation reservation proof"
        ),
        evidence={
            "queue_wait_fraction": round(frac, 4),
            "blocker_job_id": best_jid,
            "blocker_name": name,
            "blocker_width": width.get(best_jid, 1),
            "queued_job_s_overlapped": round(best_overlap, 1),
            "reservations_recorded": n_res,
        },
    )


def _check_pool_thrash(trace, n_jobs) -> Optional[Advisory]:
    evictions: dict[str, int] = {}
    evicted_bytes = 0.0
    for kind, _t, label, args in trace.events:
        if kind == "eviction":
            evictions[label] = evictions.get(label, 0) + 1
            evicted_bytes += args.get("nbytes", 0.0)
    if not evictions:
        return None
    top = max(evictions.items(), key=lambda kv: (kv[1], kv[0]))
    if top[1] < 3:
        return None
    restages = top[1] + 1                   # evicted N times => staged N+1
    return Advisory(
        code="pool_thrash",
        severity=min(1.0, 0.5 + 0.06 * top[1]),
        summary=(
            f"eviction churn: dataset {top[0]!r} re-staged {restages}x "
            f"({sum(evictions.values())} evictions total, "
            f"{evicted_bytes / 1e9:,.1f} GB evicted) — the pool is smaller "
            "than the working set"
        ),
        recommendation=(
            "grow the pool's capacity (or add a pool) so the hot datasets "
            "fit resident; churned stage-in traffic is pure waste"
        ),
        evidence={
            "top_dataset": top[0],
            "top_evictions": top[1],
            "total_evictions": sum(evictions.values()),
            "evicted_bytes": evicted_bytes,
            "datasets_churned": {
                k: v for k, v in sorted(
                    evictions.items(), key=lambda kv: (-kv[1], kv[0])
                )[:5]
            },
        },
    )


def _check_fault_churn(trace, n_jobs) -> Optional[Advisory]:
    requeued = sum(
        1 for k, _t, _l, a in trace.events if k == "fault" and a.get("requeued")
    )
    if requeued < max(3, 0.15 * n_jobs):
        return None
    checkpoints = sum(1 for e in trace.events if e[0] == "checkpoint")
    sev = min(1.0, requeued / max(1, n_jobs))
    ckpt_txt = (
        "no checkpoints were committed — every retry replays from scratch"
        if checkpoints == 0
        else f"{checkpoints} checkpoint commits bound the replay"
    )
    return Advisory(
        code="fault_churn",
        severity=sev,
        summary=(
            f"fault churn: {requeued} attempts requeued by faults across "
            f"{n_jobs} jobs; {ckpt_txt}"
        ),
        recommendation=(
            "set checkpoint_every_s/checkpoint_bytes on fault-prone specs "
            "so resumes pay only the uncommitted remainder"
        ),
        evidence={"requeued_faults": requeued, "checkpoints": checkpoints},
    )


def _check_node_churn(trace, n_jobs) -> Optional[Advisory]:
    """Storage nodes dying mid-campaign: count the losses and what they
    cost — attempts degraded or faulted, pool rebuilds paid. Fires on any
    node loss at all; severity scales with the per-job damage."""
    downs = sum(1 for e in trace.events if e[0] == "node_down")
    if downs == 0:
        return None
    repairs = sum(1 for e in trace.events if e[0] == "node_repair")
    degraded = sum(1 for e in trace.events if e[0] == "degraded")
    rebuilds = {"repair": 0, "backfill": 0}
    for kind, _t, _l, args in trace.events:
        if kind == "rebuild":
            via = args.get("via", "repair")
            rebuilds[via] = rebuilds.get(via, 0) + 1
    faults = sum(
        1 for k, _t, _l, a in trace.events if k == "fault" and a.get("requeued")
    )
    sev = min(1.0, 0.3 + 0.5 * (degraded + faults) / max(1, n_jobs))
    return Advisory(
        code="node_churn",
        severity=sev,
        summary=(
            f"node churn: {downs} storage-node failure(s) "
            f"({repairs} repaired, {rebuilds['backfill']} pool backfill(s), "
            f"{rebuilds['repair']} re-silver(s)); {degraded} attempt(s) ran "
            f"DEGRADED and {faults} requeued on faults"
        ),
        recommendation=(
            "mirror critical specs (placement.mirror with a redundancy-"
            "capable backend) and arm pool self-healing with a RetryPolicy "
            "so capacity backfills instead of waiting out the MTTR"
        ),
        evidence={
            "node_downs": downs,
            "node_repairs": repairs,
            "degraded_attempts": degraded,
            "rebuilds": rebuilds,
            "requeued_faults": faults,
        },
    )


def _check_negotiation_pressure(trace) -> Optional[Advisory]:
    failed = 0
    reasons: dict[str, int] = {}
    for kind, _t, _l, args in trace.events:
        if kind != "negotiation" or args.get("ok"):
            continue
        failed += 1
        for r in args.get("rejections", ()):
            key = f"{r['backend']}: {r['reason']}"
            reasons[key] = reasons.get(key, 0) + 1
    if failed == 0:
        return None
    top = sorted(reasons.items(), key=lambda kv: (-kv[1], kv[0]))[:3]
    return Advisory(
        code="negotiation_pressure",
        severity=min(1.0, 0.3 + 0.05 * failed),
        summary=(
            f"negotiation pressure: {failed} spec(s) found no backend; "
            "top rejection: " + (top[0][0] if top else "n/a")
        ),
        recommendation=(
            "widen the spec's manager fallbacks or register a backend with "
            "the missing capability"
        ),
        evidence={"failed_negotiations": failed, "rejections": dict(top)},
    )


def _check_serving_queue_bound(trace, metrics) -> Optional[Advisory]:
    """Serving campaigns have no job spans — their evidence is the replica
    lifecycle events plus the TTFT histogram. Fires when the TTFT tail is
    dominated by queueing: prefill cost is roughly constant per request, so
    a p99 far above p50 means requests sat in the queue waiting for a slot
    (capacity arrived too late or not at all)."""
    replica_events = [e for e in trace.events if e[0] == "replica"]
    if not replica_events or metrics is None:
        return None
    hist = metrics.histograms.get("serving/ttft_s")
    if hist is None or hist.total == 0:
        return None
    p50 = hist.percentile(0.50)
    p99 = hist.percentile(0.99)
    if p50 is None or p99 is None:
        return None
    floor = max(p50, 0.05)
    if p99 < 5.0 * floor:
        return None
    ups = sum(1 for e in trace.events
              if e[0] == "autoscale" and e[2] == "up")
    peak = max(
        (e[3].get("n_live", 0) for e in trace.events if e[0] == "autoscale"),
        default=0,
    )
    return Advisory(
        code="serving_queue_bound",
        severity=min(1.0, 0.4 + 0.06 * (p99 / floor)),
        summary=(
            f"serving queue bound: TTFT p99 {p99:.1f} s vs p50 {p50:.2f} s — "
            f"the tail is queueing for a slot, not prefill "
            f"({ups} alert-driven scale-up(s), peak fleet {peak})"
        ),
        recommendation=(
            "let capacity arrive earlier: raise max_replicas, shorten the "
            "scale-up cooldown, or lower the queue-delay alert's burn "
            "target/window so the burst trips it sooner"
        ),
        evidence={
            "ttft_p50_s": round(p50, 3),
            "ttft_p99_s": round(p99, 3),
            "scale_ups": ups,
            "peak_fleet": peak,
            "replica_events": len(replica_events),
        },
    )


def _check_pilot_underpacked(trace) -> Optional[Advisory]:
    """Pilots holding a whole node-block grant while most slots idle: the
    acquisition amortization the pilot exists for is not happening. Judged
    from the ``task_batch`` events' occupancy samples (>= 3 batches per
    pilot so a drain tail alone cannot trip it)."""
    occ: dict[str, list] = {}
    for kind, _t, label, args in trace.events:
        if kind == "task_batch":
            occ.setdefault(label, []).append(args.get("occupancy", 0.0))
    means = {
        name: sum(v) / len(v) for name, v in occ.items() if len(v) >= 3
    }
    under = sorted(
        ((m, name) for name, m in means.items() if m < 0.5)
    )
    if not under:
        return None
    worst_m, worst = under[0]
    return Advisory(
        code="pilot_underpacked",
        severity=min(1.0, 0.3 + 0.5 * (1.0 - worst_m)),
        summary=(
            f"pilot under-packed: {len(under)} of {len(means)} pilot(s) "
            f"averaged under 50% slot occupancy (worst {worst!r} at "
            f"{worst_m:.0%}) — the node-block grant is mostly idle"
        ),
        recommendation=(
            "submit more tasks per pilot, shrink n_compute/slots_per_node "
            "to match the backlog, or run the tail as plain jobs so the "
            "grant releases sooner"
        ),
        evidence={
            "worst_pilot": worst,
            "worst_mean_occupancy": round(worst_m, 4),
            "underpacked": [name for _m, name in under[:5]],
            "pilots_sampled": len(means),
        },
    )


def _check_slo_breach(slos) -> list[Advisory]:
    out = []
    for s in getattr(slos, "breached", ()):
        over = s.budget_consumed - 1.0
        out.append(
            Advisory(
                code="slo_breach",
                severity=min(1.0, 0.4 + 0.2 * over),
                summary=(
                    f"SLO {s.name!r} breached: attainment {s.attainment:.1%} "
                    f"vs objective {s.objective:.1%} (error budget "
                    f"{s.budget_consumed:.0%} spent)"
                ),
                recommendation=(
                    "treat the highest-burn window as the signal: the other "
                    "advisories name the bottleneck spending this budget"
                ),
                evidence={
                    "slo": s.name,
                    "objective": s.objective,
                    "attainment": round(s.attainment, 4),
                    "budget_consumed": round(s.budget_consumed, 4),
                    "burn_rates": s.burn_rates,
                },
            )
        )
    return out


# -- entry points -------------------------------------------------------------

def diagnose(trace, *, metrics=None, report=None, slos=None) -> tuple[Advisory, ...]:
    """Cross-reference one campaign's evidence into ranked advisories.

    ``trace`` is the campaign's :class:`~repro.obs.trace.TraceRecorder`;
    ``metrics``, the :class:`~repro.obs.metrics.MetricsHub` (falls back to
    ``trace.metrics``); ``report``, an optional
    :class:`~repro.orchestrator.metrics.CampaignReport` for pool stats;
    ``slos``, an optional :class:`~repro.obs.slo.SLOReport`. Returns
    advisories sorted most-severe first (empty tuple: nothing to flag).
    """
    if metrics is None:
        metrics = getattr(trace, "metrics", None)
    if slos is None and report is not None:
        slos = getattr(report, "slo", None)
    serving = _check_serving_queue_bound(trace, metrics)
    cp = critical_path(trace)
    if cp is None or cp.makespan_s <= 0:
        # span-free traces (serving campaigns) still get the serving check
        # and any SLO breaches; pure-empty traces stay an empty tuple
        if serving is None:
            return ()
        advisories = [serving]
        if slos is not None:
            advisories.extend(_check_slo_breach(slos))
        advisories.sort(key=lambda a: (-a.severity, a.code))
        return tuple(advisories)
    n_jobs = len(trace.spans)
    thrash = _check_pool_thrash(trace, n_jobs)
    found = [
        serving,
        thrash,
        _check_stage_in_bound(cp, trace, metrics, report, thrash is not None),
        _check_provisioning_bound(cp),
        _check_head_blocking(cp, trace),
        _check_fault_churn(trace, n_jobs),
        _check_node_churn(trace, n_jobs),
        _check_negotiation_pressure(trace),
        _check_pilot_underpacked(trace),
    ]
    advisories = [a for a in found if a is not None]
    if slos is not None:
        advisories.extend(_check_slo_breach(slos))
    advisories.sort(key=lambda a: (-a.severity, a.code))
    return tuple(advisories)


def format_advisories(advisories, *, max_n: int = 10) -> str:
    """Terminal rendering of the doctor's findings."""
    if not advisories:
        return "campaign doctor: nothing to flag"
    lines = [f"campaign doctor: {len(advisories)} advisories"]
    for i, a in enumerate(advisories[:max_n], 1):
        lines.append(f"  {i}. [{a.code}, severity {a.severity:.2f}]")
        lines.append(f"     {a.summary}")
        lines.append(f"     -> {a.recommendation}")
    return "\n".join(lines)
