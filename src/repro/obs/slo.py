"""SLO engine: declarative objectives, error budgets, multi-window burn rates.

An :class:`SLOSpec` names a *capability the campaign must keep honoring* —
queue-delay p99 under a bound, stage-in cache hit rate above a floor,
node-utilization above a floor, fault-recovery overhead under a cap — as a
measurement over :class:`~repro.obs.metrics.MetricsHub` instruments plus a
compliance objective. The :class:`SLOTracker` turns those specs into
sample-by-sample accounting on the **virtual** clock: every time the
engine's metronome drives a metrics sample (see
:meth:`~repro.obs.trace.TraceRecorder.engine_sample`), each SLO measures
its current value, judges it against the target, and records one
good/bad compliance sample. From those samples fall out:

* **attainment** — the fraction of samples in compliance so far;
* **error budget** — ``1 - objective`` is the allowed bad fraction; budget
  consumed is the observed bad fraction over that allowance;
* **burn rates** — per configured window ``W``, the bad fraction over the
  trailing ``(now - W, now]`` virtual seconds divided by the allowance. A
  burn rate of 1.0 spends the budget exactly at the sustainable pace;
  multi-window rules (fast window for pages, slow window for tickets) are
  the standard alerting construction on top (see :mod:`repro.obs.alerts`).

Like the recorder it rides on, the tracker is strictly read-only: it
never schedules events or mutates simulation state, so campaigns replay
bit-identically with SLO accounting on (``tests/test_obs.py`` holds this).

Measurements come in three shapes:

* ``series=...`` — the latest sample of a hub time series (queue depth,
  pool occupancy, cache hit rate, ...);
* ``series=..., percentile=q`` — the exact q-quantile of the series window
  (trailing ``window_s``, or the whole ring when unset);
* ``histogram=..., percentile=q`` — the bucket-interpolated q-quantile of
  a hub histogram (e.g. ``phase_s/queued`` for queue-delay p99 — the
  per-phase histograms the trace folds in as spans close).

Cold-side module: hot loops never import this (``tools/check_obs_imports``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

__all__ = [
    "SLOSpec",
    "SLOStatus",
    "SLOReport",
    "SLOTracker",
    "format_slo_report",
]

_OPS = ("<=", ">=")


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One declarative objective over a hub instrument.

    The measured value must satisfy ``value <op> target`` on at least
    ``objective`` of all compliance samples; ``burn_windows`` are the
    trailing virtual-time windows burn rates are reported over.
    """

    name: str
    target: float
    op: str = "<="
    series: Optional[str] = None
    histogram: Optional[str] = None
    percentile: Optional[float] = None
    window_s: Optional[float] = None        # series-quantile lookback
    objective: float = 0.99                 # required good fraction, (0, 1)
    burn_windows: tuple[float, ...] = (300.0, 3600.0)
    description: str = ""

    def __post_init__(self) -> None:
        if (self.series is None) == (self.histogram is None):
            raise ValueError(
                f"SLO {self.name!r}: exactly one of series= or histogram= "
                "must be set"
            )
        if self.histogram is not None and self.percentile is None:
            raise ValueError(
                f"SLO {self.name!r}: histogram measurements need percentile="
            )
        if self.op not in _OPS:
            raise ValueError(f"SLO {self.name!r}: op must be one of {_OPS}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError(
                f"SLO {self.name!r}: objective must be in (0, 1), "
                f"got {self.objective}"
            )
        if self.percentile is not None and not 0.0 <= self.percentile <= 1.0:
            raise ValueError(f"SLO {self.name!r}: percentile must be in [0, 1]")
        if any(w <= 0 for w in self.burn_windows):
            raise ValueError(f"SLO {self.name!r}: burn windows must be > 0")

    @property
    def budget(self) -> float:
        """The error budget: the allowed bad-sample fraction."""
        return 1.0 - self.objective

    def describe_objective(self) -> str:
        src = self.series if self.series is not None else self.histogram
        if self.percentile is not None:
            src = f"p{self.percentile * 100:g}({src})"
        return f"{src} {self.op} {self.target:g} for {self.objective:.1%}"


@dataclasses.dataclass(frozen=True)
class SLOStatus:
    """Point-in-time accounting for one SLO."""

    name: str
    objective_desc: str
    n_samples: int
    n_bad: int
    attainment: float            # good fraction over all samples (1.0 if none)
    objective: float
    budget_consumed: float       # bad fraction / allowed fraction
    burn_rates: dict[str, float]  # str(window_s) -> burn rate over that window
    current_value: Optional[float]
    target: float
    op: str
    ok_now: Optional[bool]       # last sample's verdict (None: unmeasurable)

    @property
    def budget_remaining(self) -> float:
        return 1.0 - self.budget_consumed

    @property
    def breached(self) -> bool:
        """The campaign-to-date attainment has fallen below the objective
        (equivalently: the error budget is overspent)."""
        return self.n_samples > 0 and self.attainment < self.objective


@dataclasses.dataclass(frozen=True)
class SLOReport:
    """All SLO statuses at one instant (what ``summarize(trace=...)``
    attaches to the campaign report)."""

    t: float
    statuses: tuple[SLOStatus, ...]

    @property
    def breached(self) -> tuple[SLOStatus, ...]:
        return tuple(s for s in self.statuses if s.breached)

    def status(self, name: str) -> SLOStatus:
        for s in self.statuses:
            if s.name == name:
                return s
        raise KeyError(name)


class _SLOState:
    """Per-SLO compliance ring + lifetime totals."""

    __slots__ = ("samples", "n", "bad", "last_value", "last_ok")

    def __init__(self, maxlen: int):
        #: trailing ``(t, bad)`` compliance samples for burn-rate windows
        self.samples: deque[tuple[float, int]] = deque(maxlen=maxlen)
        self.n = 0
        self.bad = 0
        self.last_value: Optional[float] = None
        self.last_ok: Optional[bool] = None


class SLOTracker:
    """Evaluates a set of :class:`SLOSpec` against one
    :class:`~repro.obs.metrics.MetricsHub`, one compliance sample per
    :meth:`observe` call (driven from the alert engine's metronome hook, or
    directly in tests).

    ``maxlen`` bounds the per-SLO compliance ring the burn-rate windows
    read from — windows longer than the ring covers degrade gracefully to
    the ring's span, exactly like the hub's series ring buffers.
    """

    def __init__(self, hub, specs, *, maxlen: int = 4096):
        self.hub = hub
        self.specs: tuple[SLOSpec, ...] = tuple(specs)
        names = [s.name for s in self.specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names in {names}")
        self._state = {s.name: _SLOState(maxlen) for s in self.specs}
        self._needs_trace = any(s.histogram is not None for s in self.specs)
        self.samples_taken = 0
        self.last_t: Optional[float] = None

    # -- measurement ----------------------------------------------------------
    def measure(self, spec: SLOSpec, t: float) -> Optional[float]:
        """The spec's current value at virtual time ``t`` (``None`` when the
        instrument has no data yet — no compliance sample is charged)."""
        if spec.histogram is not None:
            h = self.hub.histograms.get(spec.histogram)
            return h.percentile(spec.percentile) if h is not None else None
        s = self.hub.series.get(spec.series)
        if s is None or len(s) == 0:
            return None
        if spec.percentile is not None:
            t0 = t - spec.window_s if spec.window_s is not None else None
            return s.quantile(spec.percentile, t0=t0, t1=t)
        return s.last()[1]

    def observe(self, t: float, trace=None) -> None:
        """Record one compliance sample per SLO at virtual time ``t``.

        Histogram-backed SLOs read the per-phase histograms the trace folds
        in at materialization, so a recorder handed in is materialized
        first (incremental and read-only — the sanctioned mid-campaign
        read path).
        """
        self.samples_taken += 1
        self.last_t = t
        if self._needs_trace and trace is not None:
            trace._materialize()
        for spec in self.specs:
            st = self._state[spec.name]
            v = self.measure(spec, t)
            st.last_value = v
            if v is None:
                st.last_ok = None
                continue
            ok = (v <= spec.target) if spec.op == "<=" else (v >= spec.target)
            st.last_ok = ok
            bad = 0 if ok else 1
            st.n += 1
            st.bad += bad
            st.samples.append((t, bad))

    # -- accounting -----------------------------------------------------------
    def burn_rate(self, name: str, window_s: float, now: float) -> float:
        """Bad fraction over the trailing ``(now - window_s, now]`` divided
        by the error budget; 0.0 when the window holds no samples."""
        spec = self._spec(name)
        st = self._state[name]
        t0 = now - window_s
        n = bad = 0
        for t, b in reversed(st.samples):
            if t <= t0:
                break
            n += 1
            bad += b
        if n == 0:
            return 0.0
        return (bad / n) / spec.budget

    def status(self, name: str, now: Optional[float] = None) -> SLOStatus:
        spec = self._spec(name)
        st = self._state[name]
        now = now if now is not None else (self.last_t or 0.0)
        attainment = 1.0 - st.bad / st.n if st.n else 1.0
        consumed = (st.bad / st.n) / spec.budget if st.n else 0.0
        return SLOStatus(
            name=spec.name,
            objective_desc=spec.describe_objective(),
            n_samples=st.n,
            n_bad=st.bad,
            attainment=attainment,
            objective=spec.objective,
            budget_consumed=consumed,
            burn_rates={
                f"{w:g}": self.burn_rate(name, w, now) for w in spec.burn_windows
            },
            current_value=st.last_value,
            target=spec.target,
            op=spec.op,
            ok_now=st.last_ok,
        )

    def report(self, now: Optional[float] = None) -> SLOReport:
        now = now if now is not None else (self.last_t or 0.0)
        return SLOReport(
            t=now,
            statuses=tuple(self.status(s.name, now) for s in self.specs),
        )

    def _spec(self, name: str) -> SLOSpec:
        for s in self.specs:
            if s.name == name:
                return s
        raise KeyError(f"unknown SLO {name!r}")


def format_slo_report(report: SLOReport) -> str:
    """Terminal table: one line per SLO with attainment, budget, burns."""
    if not report.statuses:
        return "SLOs: none defined"
    lines = [f"SLOs at t={report.t:,.1f}s (virtual):"]
    for s in report.statuses:
        burns = "  ".join(
            f"burn[{w}s]={r:.2f}" for w, r in s.burn_rates.items()
        )
        cur = "-" if s.current_value is None else f"{s.current_value:g}"
        flag = "BREACHED" if s.breached else "ok"
        lines.append(
            f"  {s.name:<24} {flag:<9} attain={s.attainment:.3%} "
            f"(objective {s.objective:.1%}, {s.n_bad}/{s.n_samples} bad)  "
            f"budget={s.budget_remaining:+.1%}  {burns}  now={cur} "
            f"(want {s.op} {s.target:g})"
        )
    return "\n".join(lines)
