"""Time-series metrics: counters, gauges, histograms, ring-buffer series.

A :class:`MetricsHub` is the one handle a :class:`~repro.obs.trace.TraceRecorder`
carries. Probes (zero-arg callables reading live orchestrator state) are
registered once and sampled on a virtual-time cadence; every sample lands
in a bounded ring buffer, so a 50k-job campaign's dashboard series stay
O(maxlen) regardless of length. Nothing here schedules engine events or
mutates simulation state — sampling is pull-only.

Pure stdlib, no ``repro`` imports: usable from any layer without cycles.
"""

from __future__ import annotations

import bisect
from collections import deque
from typing import Callable, Iterable, Optional

#: Default histogram bucket upper bounds (seconds-flavored, but buckets are
#: unit-agnostic); one overflow bucket is implied past the last bound.
DEFAULT_BOUNDS: tuple[float, ...] = (0.1, 1.0, 10.0, 60.0, 300.0, 1800.0, 7200.0)


class Counter:
    """Monotonically increasing total."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram: counts per upper bound plus an overflow
    bucket; tracks total/sum/min/max for cheap summary stats."""

    __slots__ = ("name", "bounds", "counts", "total", "sum", "min", "max")

    def __init__(self, name: str, bounds: Iterable[float] = DEFAULT_BOUNDS):
        self.name = name
        self.bounds = tuple(sorted(bounds))
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0


class TimeSeries:
    """Bounded ``(t, value)`` ring buffer — old samples fall off the front."""

    __slots__ = ("name", "_buf")

    def __init__(self, name: str, maxlen: int = 4096):
        self.name = name
        self._buf: deque[tuple[float, float]] = deque(maxlen=maxlen)

    def append(self, t: float, v: float) -> None:
        self._buf.append((t, v))

    def items(self) -> list[tuple[float, float]]:
        return list(self._buf)

    def last(self) -> Optional[tuple[float, float]]:
        return self._buf[-1] if self._buf else None

    def __len__(self) -> int:
        return len(self._buf)


class MetricsHub:
    """Registry of named instruments plus the probe-sampling driver."""

    def __init__(self, *, maxlen: int = 4096):
        self.maxlen = maxlen
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.series: dict[str, TimeSeries] = {}
        self._probes: list[tuple[str, Callable[[], float]]] = []
        self.samples_taken = 0

    # -- instruments (get-or-create) ------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(
        self, name: str, bounds: Iterable[float] = DEFAULT_BOUNDS
    ) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, bounds)
        return h

    # -- time series ----------------------------------------------------------
    def record(self, name: str, t: float, v: float) -> None:
        """Append one ``(t, v)`` sample to the named series."""
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = TimeSeries(name, maxlen=self.maxlen)
        s.append(t, v)

    # -- probes ---------------------------------------------------------------
    def add_probe(self, name: str, fn: Callable[[], float]) -> None:
        """Register a zero-arg read-only callable sampled by :meth:`sample`."""
        self._probes.append((name, fn))

    def sample(self, t: float) -> None:
        """Read every probe once and append to its series (and gauge)."""
        self.samples_taken += 1
        for name, fn in self._probes:
            v = fn()
            self.record(name, t, v)
            self.gauge(name).value = v

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-data summary (JSON-serializable)."""
        return {
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.value for k, g in self.gauges.items()},
            "histograms": {
                k: {
                    "bounds": list(h.bounds),
                    "counts": list(h.counts),
                    "total": h.total,
                    "mean": h.mean,
                    "min": h.min,
                    "max": h.max,
                }
                for k, h in self.histograms.items()
            },
            "series": {k: s.items() for k, s in self.series.items()},
        }
