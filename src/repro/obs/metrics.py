"""Time-series metrics: counters, gauges, histograms, ring-buffer series.

A :class:`MetricsHub` is the one handle a :class:`~repro.obs.trace.TraceRecorder`
carries. Probes (zero-arg callables reading live orchestrator state) are
registered once and sampled on a virtual-time cadence; every sample lands
in a bounded ring buffer, so a 50k-job campaign's dashboard series stay
O(maxlen) regardless of length. Nothing here schedules engine events or
mutates simulation state — sampling is pull-only.

Pure stdlib, no ``repro`` imports: usable from any layer without cycles.
"""

from __future__ import annotations

import bisect
import dataclasses
from collections import deque
from typing import Callable, Iterable, Optional

#: Default histogram bucket upper bounds (seconds-flavored, but buckets are
#: unit-agnostic); one overflow bucket is implied past the last bound.
DEFAULT_BOUNDS: tuple[float, ...] = (0.1, 1.0, 10.0, 60.0, 300.0, 1800.0, 7200.0)


class Counter:
    """Monotonically increasing total."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram: counts per upper bound plus an overflow
    bucket; tracks total/sum/min/max for cheap summary stats."""

    __slots__ = ("name", "bounds", "counts", "total", "sum", "min", "max")

    def __init__(self, name: str, bounds: Iterable[float] = DEFAULT_BOUNDS):
        self.name = name
        self.bounds = tuple(sorted(bounds))
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0

    def percentile(self, q: float) -> Optional[float]:
        """Bucket-interpolated quantile estimate, ``q`` in [0, 1].

        Walks the cumulative counts to the bucket holding rank ``q * total``
        and interpolates linearly inside it; the first bucket's lower edge
        is the observed minimum and the overflow bucket's upper edge the
        observed maximum, and the result is clamped to ``[min, max]`` (so a
        degenerate one-value histogram answers exactly). The error is
        bounded by the width of the bucket the quantile lands in. ``None``
        when nothing was observed.
        """
        if self.total == 0:
            return None
        q = min(1.0, max(0.0, q))
        target = q * self.total
        cum = 0
        bounds = self.bounds
        for i, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo = bounds[i - 1] if i > 0 else self.min
                hi = bounds[i] if i < len(bounds) else self.max
                frac = (target - cum) / c
                v = lo + frac * (hi - lo)
                return min(self.max, max(self.min, v))
            cum += c
        return self.max


@dataclasses.dataclass(frozen=True)
class SeriesWindowAgg:
    """Summary of the samples of one :class:`TimeSeries` window."""

    n: int
    min: float
    max: float
    mean: float
    t_first: float
    t_last: float


class TimeSeries:
    """Bounded ``(t, value)`` ring buffer — old samples fall off the front.

    ``appended`` counts every sample ever appended, so consumers can tell a
    full campaign history from a ring that has dropped its oldest samples
    (``appended > len(series)`` means the front fell off).
    """

    __slots__ = ("name", "_buf", "appended")

    def __init__(self, name: str, maxlen: int = 4096):
        self.name = name
        self._buf: deque[tuple[float, float]] = deque(maxlen=maxlen)
        self.appended = 0

    def append(self, t: float, v: float) -> None:
        self.appended += 1
        self._buf.append((t, v))

    def items(self) -> list[tuple[float, float]]:
        return list(self._buf)

    def last(self) -> Optional[tuple[float, float]]:
        return self._buf[-1] if self._buf else None

    def __len__(self) -> int:
        return len(self._buf)

    # -- windowed reads (timestamps are appended in nondecreasing order) ------
    def window(
        self, t0: Optional[float] = None, t1: Optional[float] = None
    ) -> list[tuple[float, float]]:
        """Samples with ``t0 <= t <= t1`` (either bound optional)."""
        items = list(self._buf)
        if not items:
            return items
        times = [t for t, _ in items]
        lo = 0 if t0 is None else bisect.bisect_left(times, t0)
        hi = len(items) if t1 is None else bisect.bisect_right(times, t1)
        return items[lo:hi]

    def agg(
        self, t0: Optional[float] = None, t1: Optional[float] = None
    ) -> Optional[SeriesWindowAgg]:
        """Min/max/mean summary of the window; ``None`` when it is empty."""
        win = self.window(t0, t1)
        if not win:
            return None
        vals = [v for _, v in win]
        return SeriesWindowAgg(
            n=len(vals),
            min=min(vals),
            max=max(vals),
            mean=sum(vals) / len(vals),
            t_first=win[0][0],
            t_last=win[-1][0],
        )

    def quantile(
        self,
        q: float,
        t0: Optional[float] = None,
        t1: Optional[float] = None,
    ) -> Optional[float]:
        """Exact linear-interpolated quantile of the window's sample values
        (the series keeps raw samples, so no bucket error here); ``None``
        when the window is empty."""
        win = self.window(t0, t1)
        if not win:
            return None
        vals = sorted(v for _, v in win)
        if len(vals) == 1:
            return vals[0]
        q = min(1.0, max(0.0, q))
        pos = q * (len(vals) - 1)
        i = int(pos)
        frac = pos - i
        if frac == 0.0 or i + 1 >= len(vals):
            return vals[i]
        return vals[i] + frac * (vals[i + 1] - vals[i])


class MetricsHub:
    """Registry of named instruments plus the probe-sampling driver."""

    def __init__(self, *, maxlen: int = 4096):
        self.maxlen = maxlen
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.series: dict[str, TimeSeries] = {}
        self._probes: list[tuple[str, Callable[[], float]]] = []
        self.samples_taken = 0

    # -- instruments (get-or-create) ------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(
        self, name: str, bounds: Iterable[float] = DEFAULT_BOUNDS
    ) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, bounds)
        return h

    # -- time series ----------------------------------------------------------
    def record(self, name: str, t: float, v: float) -> None:
        """Append one ``(t, v)`` sample to the named series."""
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = TimeSeries(name, maxlen=self.maxlen)
        s.append(t, v)

    # -- probes ---------------------------------------------------------------
    def add_probe(self, name: str, fn: Callable[[], float]) -> None:
        """Register a zero-arg read-only callable sampled by :meth:`sample`."""
        self._probes.append((name, fn))

    def sample(self, t: float) -> None:
        """Read every probe once and append to its series (and gauge)."""
        self.samples_taken += 1
        for name, fn in self._probes:
            v = fn()
            self.record(name, t, v)
            self.gauge(name).value = v

    # -- export ---------------------------------------------------------------
    def snapshot(self, *, max_points: Optional[int] = None) -> dict:
        """Plain-data summary (JSON-serializable).

        Histograms carry interpolated ``p50``/``p95``/``p99`` next to the
        raw buckets. Each series exports as a dict — not a bare point list —
        so consumers can't mistake a truncated series for the full campaign:

        * ``points`` — ``[t, v]`` pairs, at most ``max_points`` of them
          (default: the hub's ring ``maxlen``). Longer series are
          down-sampled deterministically on an even index stride that always
          keeps the first and last sample.
        * ``n_points`` / ``n_appended`` — exported vs ever-recorded counts.
        * ``truncated`` — ``True`` when ``points`` is not the full history
          (the ring dropped old samples and/or the export down-sampled).
        """
        cap = self.maxlen if max_points is None else max_points
        series: dict[str, dict] = {}
        for k, s in self.series.items():
            pts = s.items()
            downsampled = False
            if cap > 0 and len(pts) > cap:
                downsampled = True
                if cap == 1:
                    pts = [pts[-1]]
                else:
                    n = len(pts)
                    idx = sorted({round(i * (n - 1) / (cap - 1)) for i in range(cap)})
                    pts = [pts[i] for i in idx]
            series[k] = {
                "points": [[t, v] for t, v in pts],
                "n_points": len(pts),
                "n_appended": s.appended,
                "truncated": downsampled or s.appended > len(s),
            }
        return {
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.value for k, g in self.gauges.items()},
            "histograms": {
                k: {
                    "bounds": list(h.bounds),
                    "counts": list(h.counts),
                    "total": h.total,
                    "mean": h.mean,
                    "min": h.min,
                    "max": h.max,
                    "p50": h.percentile(0.50),
                    "p95": h.percentile(0.95),
                    "p99": h.percentile(0.99),
                }
                for k, h in self.histograms.items()
            },
            "series": series,
        }
