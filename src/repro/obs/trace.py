"""Trace recorder: the event bus every hot layer emits into.

This module is the *only* observability surface the hot loops are allowed
to touch (``tools/check_obs_imports.py`` enforces it): it imports nothing
from the rest of ``repro``, so engine/lifecycle/provision/pool/scheduler
can depend on it without cycles or import-time cost.

Two implementations share one duck-typed interface:

* :class:`NullRecorder` — the default. ``enabled`` is ``False`` at class
  level, every method is a no-op, and every call site guards with
  ``if rec.enabled:`` so the off path costs one attribute read. The
  module-level :data:`NULL_RECORDER` singleton is what components hold
  when no tracing was requested.
* :class:`TraceRecorder` — records typed spans and events keyed on the
  **virtual** clock. It is strictly read-only with respect to engine
  state: it never schedules events, never mutates jobs/sessions/pools,
  and stamps time itself through a bound clock — so a campaign replayed
  with the recorder on produces bit-identical ``JobRecord.history``
  (``tests/test_obs.py`` holds this).

The recorder is a *flight recorder*: the highest-frequency hook
(:meth:`TraceRecorder.transition`, ~8 calls per job) only appends one
raw tuple to a list. Building per-job phase spans, job metadata, and the
per-phase duration histograms is deferred to :meth:`_materialize`, which
runs on first access to :attr:`spans` / :attr:`job_meta` (or any export
or report built on them) and is incremental — a live dashboard can read
mid-campaign and the recorder keeps appending after. This is what keeps
tracing-on throughput within the ``benchmarks/obs_bench.py`` overhead
bound.

Wiring is one call: ``TraceRecorder(...).bind(orch)`` (done automatically
by ``Orchestrator(recorder=...)``) installs the recorder on the engine,
the provisioning service, the scheduler, the pool manager and its
evictor, and registers the default time-series probes when a
:class:`~repro.obs.metrics.MetricsHub` is attached.

What gets recorded, per layer:

* lifecycle — every state transition (closed into per-phase spans),
  grants (with the release that *enabled* them, when one landed at the
  same instant — the causal edge the critical-path profiler walks),
  faults/requeues, checkpoint commits, preemptions, EASY reservations.
* provisioning — real negotiations with per-backend rejection reasons;
  offer-cache hits are counted, not evented (a 50k-job campaign would
  otherwise drown the trace in identical records).
* pools — pool create/retire/teardown, lease attach (with dataset
  hits/misses) and release, per-victim evictions.
* scheduler — grant/release counters (the per-job detail already rides
  on the lifecycle events; pools' node allocations are counted here too).
* engine — periodic heap-depth samples (every 512 events) that double as
  the metronome for time-driven metrics sampling.
"""

from __future__ import annotations

from typing import Callable, Optional


class NullRecorder:
    """Do-nothing recorder: the default wired into every component.

    ``enabled`` is a class attribute so the hot-path guard
    ``if rec.enabled:`` is a plain attribute load. The methods exist so
    un-guarded (cold-path) call sites still work against either
    implementation.
    """

    enabled = False
    #: no active layer either — ``Orchestrator.alerts`` reads this
    alerts = None
    __slots__ = ()

    def bind(self, orch) -> "NullRecorder":
        return self

    def bind_engine(self, engine, service=None) -> "NullRecorder":
        return self

    # lifecycle
    def transition(self, job, state) -> None: ...
    def grant(self, job, session) -> None: ...
    def release(self, job) -> None: ...
    def fault(self, job, phase, requeued) -> None: ...
    def checkpoint(self, job) -> None: ...
    def preemption(self, victim) -> None: ...
    def reservation(self, job_id, start_at) -> None: ...

    # provisioning
    def negotiation(self, spec_name, backend, *, cached, rejections=()) -> None: ...
    def session_opened(self, backend) -> None: ...
    def session_released(self, backend) -> None: ...

    # pools
    def pool_created(self, pool, t) -> None: ...
    def pool_retired(self, pool, t) -> None: ...
    def pool_torn_down(self, pool, t) -> None: ...
    def lease_attached(self, lease, pool, n_hits, n_misses, t) -> None: ...
    def lease_released(self, lease, t) -> None: ...
    def eviction(self, pool_id, dataset_name, nbytes) -> None: ...

    # chaos (node failure domain)
    def node_down(self, node_id, t) -> None: ...
    def node_repair(self, node_id, t) -> None: ...
    def degraded(self, job, node_id, t) -> None: ...
    def rebuild(self, pool, node_id, *, via, t) -> None: ...

    # pilots (two-level scheduling)
    def pilot_started(self, name, job_id, t, *, n_tasks, n_slots, packed) -> None: ...
    def task_batch(
        self, name, job_id, t, *,
        completed, failed, requeued, packed, queued, running, occupancy,
    ) -> None: ...
    def pilot_resized(self, name, job_id, t, *, n_slots, cause, packed) -> None: ...

    # scheduler
    def sched_grant(self, allocation) -> None: ...
    def sched_release(self, allocation) -> None: ...

    # engine
    def engine_sample(self, t, heap_len, events_processed) -> None: ...


#: Shared no-op instance — components default to this, never to ``None``.
NULL_RECORDER = NullRecorder()

#: Phases a terminal transition closes with a zero-length marker instead
#: of opening a new span.
_TERMINAL = ("done", "failed")


class TraceRecorder:
    """Records spans/events from a campaign, keyed on the virtual clock.

    Parameters
    ----------
    metrics:
        Optional :class:`~repro.obs.metrics.MetricsHub`. When attached,
        probes registered by :meth:`bind` are sampled every
        ``sample_every_s`` virtual seconds (driven from the engine's
        periodic ``engine_sample`` metronome — the recorder never
        schedules events itself), and per-phase duration histograms are
        folded in when the trace materializes.
    sample_every_s:
        Virtual-time cadence for probe sampling.
    alerts:
        Optional active layer (duck-typed — an
        :class:`~repro.obs.alerts.AlertEngine`): anything exposing
        ``evaluate(t, trace)``. Evaluated right after each metrics sample
        on the same metronome cadence — alerting never adds engine events,
        and like the recorder itself it must stay read-only so traced
        campaigns replay bit-identically. Requires ``metrics``.
    clock:
        Virtual-time source; :meth:`bind` replaces it with the bound
        engine's clock.
    """

    enabled = True

    def __init__(
        self,
        *,
        metrics=None,
        sample_every_s: float = 60.0,
        alerts=None,
        clock: Optional[Callable[[], float]] = None,
    ):
        if alerts is not None and metrics is None:
            raise ValueError(
                "alerts= needs metrics=: rules read the hub's series and the "
                "engine is evaluated on the metrics sample cadence"
            )
        self.metrics = metrics
        self.alerts = alerts
        self.sample_every_s = sample_every_s
        self._clock: Callable[[], float] = clock or (lambda: 0.0)
        #: flat typed event log: ``(kind, t, label, args-dict)``.
        self.events: list[tuple[str, float, str, dict]] = []
        #: closed storage-session intervals ``(job_id, backend, pool_id, t0, t1)``.
        self.sessions: list[tuple[int, Optional[str], Optional[int], float, float]] = []
        #: job_id -> ``[(t, enabling_job_id | None), ...]`` per grant — the
        #: causal edges the critical-path walk follows out of queue waits.
        self.grant_causes: dict[int, list[tuple[float, Optional[int]]]] = {}
        #: cheap named counters (cache hits, scheduler grants, ...).
        self.counts: dict[str, int] = {}
        # flight-recorder buffer: ``transition`` appends ``(job, state, t)``
        # and nothing else; ``_materialize`` drains it into ``_spans`` /
        # ``_job_meta``. ``_raw_append`` is the pre-bound list method so the
        # hot path is a single call (rebound whenever the buffer is swapped).
        self._raw: list[tuple] = []
        self._raw_append = self._raw.append
        self._spans: dict[int, list[tuple[str, float, float]]] = {}
        self._job_meta: dict[int, dict] = {}
        #: job_id -> (backend, pool_id) from the latest grant, merged into
        #: ``job_meta`` at materialize time (grants must not force one).
        self._job_backend: dict[int, tuple] = {}
        # materialize-time caches: the open-phase entry carries the job's
        # spans list (no per-tuple dict lookup into ``_spans``), enum ->
        # phase-string and per-phase histogram handles are memoized (the
        # enum ``.value`` descriptor and hub lookups are measurable at
        # 50k-job scale)
        self._open_phase: dict[int, tuple[str, float, list]] = {}
        self._state_names: dict = {}
        self._phase_hist: dict = {}
        self._count_keys: dict[tuple, str] = {}
        self._open_sessions: dict[int, tuple[Optional[str], Optional[int], float]] = {}
        self._last_release: tuple[Optional[int], Optional[float]] = (None, None)
        self._last_reservation: Optional[tuple] = None
        self._last_sample: Optional[float] = None

    # -- wiring ---------------------------------------------------------------
    def bind(self, orch) -> "TraceRecorder":
        """Install this recorder across one orchestrator's stack and bind
        the virtual clock. Returns self (chainable)."""
        engine = orch.engine
        # read the engine's clock field directly: the ``now`` property costs
        # a descriptor call per recorded event
        self._clock = lambda: engine._now
        engine.recorder = self
        orch.provision.recorder = self   # propagates: scheduler, pools, evictor
        if self.metrics is not None:
            self._register_probes(orch)
        return self

    def bind_engine(self, engine, service=None) -> "TraceRecorder":
        """Bind to a bare :class:`SimEngine` — for drivers that are not an
        orchestrator (the serving campaign): installs the virtual clock and
        the engine metronome, and optionally hooks a
        :class:`~repro.provision.ProvisioningService` so session/pool/lease
        events land in this trace. Probes are the caller's to register on
        the hub directly. Returns self (chainable)."""
        self._clock = lambda: engine._now
        engine.recorder = self
        if service is not None:
            service.recorder = self
        return self

    def _register_probes(self, orch) -> None:
        hub = self.metrics
        sched = orch.scheduler
        counters = orch.counters
        hub.add_probe("queue_depth", lambda: len(orch.queue))
        hub.add_probe("free_compute_nodes", lambda: len(sched._free_compute))
        hub.add_probe("free_storage_nodes", lambda: len(sched._free_storage))
        hub.add_probe("running_jobs", lambda: len(orch._running))
        hub.add_probe("jobs_done", lambda: counters.n_done)
        hub.add_probe("jobs_failed", lambda: counters.n_failed)
        # healthy fraction of storage capacity — 1.0 the whole campaign
        # unless a chaos model is killing nodes
        hub.add_probe("availability", lambda: sched.healthy_capacity_fraction)

        def pool_occupancy() -> float:
            pm = orch.provision.pool_manager
            return pm.occupancy() if pm is not None else 0.0

        def catalog_hit_rate() -> float:
            pm = orch.provision.pool_manager
            return pm.stats.hit_rate if pm is not None else 0.0

        hub.add_probe("pool_occupancy", pool_occupancy)
        hub.add_probe("catalog_hit_rate", catalog_hit_rate)
        hub.add_probe("tasks_done", lambda: counters.tasks_done)

        def pilot_occupancy() -> float:
            # mean slot occupancy over RUNNING pilots (0.0 when none)
            total = n = 0.0
            for job in orch._running.values():
                if job.pilot is not None:
                    total += job.pilot.tasks.occupancy
                    n += 1
            return total / n if n else 0.0

        hub.add_probe("pilot_occupancy", pilot_occupancy)

    # -- internals ------------------------------------------------------------
    def now(self) -> float:
        return self._clock()

    def count(self, key: str, n: int = 1) -> None:
        c = self.counts
        c[key] = c.get(key, 0) + n

    def _tick(self, t: float) -> None:
        """Drive time-based metric sampling — and alert evaluation — off
        recorded activity. Alerts run on exactly the sample cadence, right
        after the probes, so rules always judge fresh series."""
        hub = self.metrics
        if hub is None:
            return
        last = self._last_sample
        if last is None or t - last >= self.sample_every_s:
            self._last_sample = t
            hub.sample(t)
            alerts = self.alerts
            if alerts is not None:
                alerts.evaluate(t, self)

    # -- materialization ------------------------------------------------------
    @property
    def spans(self) -> dict[int, list[tuple[str, float, float]]]:
        """job_id -> closed ``(phase, t0, t1)`` spans, in time order.
        Terminal markers are zero-length ``("done"/"failed", t, t)``.
        Access materializes any buffered transitions first."""
        self._materialize()
        return self._spans

    @property
    def job_meta(self) -> dict[int, dict]:
        """job_id -> {"name", "submit", "backend", "pool_id", "priority"}.
        Access materializes any buffered transitions first."""
        self._materialize()
        return self._job_meta

    def _materialize(self) -> None:
        """Drain the raw transition buffer into spans/meta/histograms.

        Incremental and idempotent: open phases survive across calls, so a
        mid-campaign read sees every span closed so far and later appends
        keep extending the same structures.
        """
        raw = self._raw
        if raw:
            self._raw = []
            self._raw_append = self._raw.append
            names = self._state_names
            hub = self.metrics
            open_ = self._open_phase
            phase_hist = self._phase_hist
            spans_by_job = self._spans
            meta_by_job = self._job_meta
            for job, state, t in raw:
                jid = job.job_id
                phase = names.get(state)
                if phase is None:
                    phase = names[state] = state.value
                entry = open_.get(jid)
                if entry is not None:
                    prev, t0, spans = entry
                    spans.append((prev, t0, t))
                    if hub is not None:
                        hist = phase_hist.get(prev)
                        if hist is None:
                            hist = phase_hist[prev] = hub.histogram("phase_s/" + prev)
                        hist.observe(t - t0)
                else:
                    spans = spans_by_job.get(jid)
                    if spans is None:
                        spans = spans_by_job[jid] = []
                        spec = job.spec
                        meta_by_job[jid] = {
                            "name": spec.name,
                            "submit": job.submit_time,
                            "priority": spec.priority,
                        }
                if phase in _TERMINAL:
                    open_.pop(jid, None)
                    spans.append((phase, t, t))
                else:
                    open_[jid] = (phase, t, spans)
        if self._job_backend:
            meta_by_job = self._job_meta
            for jid, (backend, pool_id) in self._job_backend.items():
                meta = meta_by_job.get(jid)
                if meta is not None:
                    meta["backend"] = backend
                    if pool_id is not None:
                        meta["pool_id"] = pool_id
            self._job_backend.clear()

    # -- lifecycle ------------------------------------------------------------
    def transition(self, job, state) -> None:
        # hottest hook in the recorder (~8 calls/job): append one tuple,
        # defer everything else to ``_materialize``
        self._raw_append((job, state, self._clock()))

    def grant(self, job, session) -> None:
        t = self._clock()
        jid = job.job_id
        rel_id, rel_t = self._last_release
        cause = rel_id if (rel_t == t and rel_id != jid) else None
        self.grant_causes.setdefault(jid, []).append((t, cause))
        lease = session.lease
        pool_id = lease.pool_id if lease is not None else None
        self._job_backend[jid] = (session.backend, pool_id)
        self._open_sessions[jid] = (session.backend, pool_id, t)
        alloc = session.allocation
        self.events.append(
            (
                "grant",
                t,
                job.spec.name,
                {
                    "job_id": jid,
                    "attempt": job.attempt,
                    "backend": session.backend,
                    "pool_id": pool_id,
                    "n_compute": len(alloc.compute_nodes) if alloc else 0,
                    "n_storage": len(alloc.storage_nodes) if alloc else 0,
                    "enabled_by": cause,
                },
            )
        )

    def release(self, job) -> None:
        t = self._clock()
        jid = job.job_id
        self._last_release = (jid, t)
        open_ = self._open_sessions.pop(jid, None)
        if open_ is not None:
            backend, pool_id, t0 = open_
            self.sessions.append((jid, backend, pool_id, t0, t))

    def fault(self, job, phase, requeued) -> None:
        t = self._clock()
        if requeued:
            self.count("fault.requeued")
        self.events.append(
            (
                "fault",
                t,
                job.spec.name,
                {
                    "job_id": job.job_id,
                    "phase": phase,
                    "requeued": requeued,
                    "attempt": job.attempt,
                },
            )
        )

    def checkpoint(self, job) -> None:
        t = self._clock()
        self.events.append(
            (
                "checkpoint",
                t,
                job.spec.name,
                {
                    "job_id": job.job_id,
                    "committed_run_s": job.committed_run_s,
                    "n": job.checkpoints_committed,
                },
            )
        )

    def preemption(self, victim) -> None:
        t = self._clock()
        self.events.append(
            (
                "preempt",
                t,
                victim.spec.name,
                {
                    "job_id": victim.job_id,
                    "committed_run_s": victim.committed_run_s,
                    "preemptions": victim.preemptions,
                },
            )
        )

    def reservation(self, job_id, start_at) -> None:
        # a reserving policy re-books on every blocked scan; record changes
        key = (job_id, start_at)
        if key == self._last_reservation:
            return
        self._last_reservation = key
        t = self._clock()
        self.events.append(
            ("reservation", t, f"job {job_id}", {"job_id": job_id, "start_at": start_at})
        )

    # -- provisioning ---------------------------------------------------------
    def negotiation(self, spec_name, backend, *, cached, rejections=()) -> None:
        if cached:
            self.count("negotiation.cache_hits")
            return
        t = self._clock()
        self.count("negotiation.scored")
        self.events.append(
            (
                "negotiation",
                t,
                spec_name,
                {
                    "backend": backend,
                    "ok": backend is not None,
                    "rejections": [
                        {"backend": r.backend, "reason": r.reason} for r in rejections
                    ],
                },
            )
        )

    def session_opened(self, backend) -> None:
        self.count(self._count_key("sessions.opened.", backend))

    def session_released(self, backend) -> None:
        self.count(self._count_key("sessions.released.", backend))

    def _count_key(self, prefix: str, backend) -> str:
        keys = self._count_keys
        k = keys.get((prefix, backend))
        if k is None:
            k = keys[(prefix, backend)] = prefix + str(backend)
        return k

    # -- pools ----------------------------------------------------------------
    def pool_created(self, pool, t) -> None:
        self.events.append(
            (
                "pool_created",
                t,
                f"pool {pool.pool_id}",
                {
                    "pool_id": pool.pool_id,
                    "n_nodes": len(pool.allocation.storage_nodes),
                    "capacity_bytes": pool.capacity_bytes,
                },
            )
        )

    def pool_retired(self, pool, t) -> None:
        self.events.append(
            ("pool_retired", t, f"pool {pool.pool_id}", {"pool_id": pool.pool_id})
        )

    def pool_torn_down(self, pool, t) -> None:
        self.events.append(
            ("pool_torn_down", t, f"pool {pool.pool_id}", {"pool_id": pool.pool_id})
        )

    def lease_attached(self, lease, pool, n_hits, n_misses, t) -> None:
        self.events.append(
            (
                "lease_attached",
                t,
                lease.job_name,
                {
                    "pool_id": pool.pool_id,
                    "hits": n_hits,
                    "misses": n_misses,
                },
            )
        )

    def lease_released(self, lease, t) -> None:
        self.events.append(
            (
                "lease_released",
                t,
                lease.job_name,
                {"pool_id": lease.pool_id},
            )
        )

    def eviction(self, pool_id, dataset_name, nbytes) -> None:
        t = self._clock()
        self.count("pool.evictions")
        self.events.append(
            (
                "eviction",
                t,
                dataset_name,
                {"pool_id": pool_id, "nbytes": nbytes},
            )
        )

    # -- chaos (node failure domain) -------------------------------------------
    def node_down(self, node_id, t) -> None:
        self.count("chaos.node_downs")
        self.events.append(("node_down", t, node_id, {"node_id": node_id}))

    def node_repair(self, node_id, t) -> None:
        self.count("chaos.node_repairs")
        self.events.append(("node_repair", t, node_id, {"node_id": node_id}))

    def degraded(self, job, node_id, t) -> None:
        self.count("chaos.degraded")
        self.events.append(
            (
                "degraded",
                t,
                job.spec.name,
                {"job_id": job.job_id, "node_id": node_id, "attempt": job.attempt},
            )
        )

    def rebuild(self, pool, node_id, *, via, t) -> None:
        self.count("chaos.rebuilds")
        self.events.append(
            (
                "rebuild",
                t,
                f"pool {pool.pool_id}",
                {"pool_id": pool.pool_id, "node_id": node_id, "via": via},
            )
        )

    # -- pilots (two-level scheduling) -----------------------------------------
    def pilot_started(self, name, job_id, t, *, n_tasks, n_slots, packed) -> None:
        self.count("pilot.started")
        self.events.append(
            (
                "pilot_started",
                t,
                name,
                {
                    "job_id": job_id, "n_tasks": n_tasks,
                    "n_slots": n_slots, "packed": packed,
                },
            )
        )

    def task_batch(
        self, name, job_id, t, *,
        completed, failed, requeued, packed, queued, running, occupancy,
    ) -> None:
        """One coalesced completion batch inside a pilot — the O(1) event
        the engine sees in place of per-task lifecycles. Also feeds the
        per-pilot occupancy series (``pilot_occupancy/<name>``)."""
        self.count("pilot.batches")
        if completed:
            self.count("pilot.tasks_done", completed)
        if failed:
            self.count("pilot.tasks_failed", failed)
        if requeued:
            self.count("pilot.task_retries", requeued)
        self.events.append(
            (
                "task_batch",
                t,
                name,
                {
                    "job_id": job_id, "completed": completed, "failed": failed,
                    "requeued": requeued, "packed": packed, "queued": queued,
                    "running": running, "occupancy": occupancy,
                },
            )
        )
        hub = self.metrics
        if hub is not None:
            hub.record("pilot_occupancy/" + name, t, occupancy)

    def pilot_resized(self, name, job_id, t, *, n_slots, cause, packed) -> None:
        self.count("pilot.resized")
        self.events.append(
            (
                "pilot_resized",
                t,
                name,
                {
                    "job_id": job_id, "n_slots": n_slots,
                    "cause": cause, "packed": packed,
                },
            )
        )

    # -- scheduler ------------------------------------------------------------
    def sched_grant(self, allocation) -> None:
        self.count("scheduler.grants")

    def sched_release(self, allocation) -> None:
        self.count("scheduler.releases")

    # -- engine ---------------------------------------------------------------
    def engine_sample(self, t, heap_len, events_processed) -> None:
        hub = self.metrics
        if hub is not None:
            hub.record("engine_heap_depth", t, heap_len)
        self._tick(t)

    # -- introspection --------------------------------------------------------
    @property
    def n_spans(self) -> int:
        return sum(len(v) for v in self.spans.values())

    def t_range(self) -> tuple[float, float]:
        """(earliest submit-or-span start, latest span end) over the trace;
        ``(0.0, 0.0)`` when nothing was recorded."""
        if not self.spans and not self.job_meta:
            # span-free traces (e.g. serving campaigns record only typed
            # events) still have a meaningful window: the event timestamps
            if self.events:
                ts = [e[1] for e in self.events]
                return (min(ts), max(ts))
            return (0.0, 0.0)
        starts = [m["submit"] for m in self.job_meta.values()]
        t_end = 0.0
        for spans in self.spans.values():
            if spans:
                starts.append(spans[0][1])
                t_end = max(t_end, spans[-1][2])
        t0 = min(starts) if starts else 0.0
        return (t0, max(t_end, t0))
