"""Alert engine: threshold / rate-of-change / budget-burn rules with
hysteresis, evaluated on the engine's metronome — never on its own events.

The :class:`AlertEngine` is the *active* half of the observability layer:
where the :class:`~repro.obs.trace.TraceRecorder` passively records what
happened, the alert engine judges the live metric streams against rules
while the campaign runs. It stays strictly read-only with respect to the
simulation — evaluation happens inside the recorder's existing 512-event
metronome sample hook (``TraceRecorder._tick``, right after the metrics
hub samples its probes), schedules nothing, and mutates nothing outside
its own state — so campaigns replay bit-identically with alerting on
(``tests/test_obs.py`` holds this).

Rule kinds:

* ``threshold`` — the latest sample of ``series`` compares true against
  ``target`` (e.g. ``queue_depth >= 50``);
* ``rate`` — the series' average slope per virtual second over the
  trailing ``window_s`` compares true against ``target`` (e.g. queue depth
  growing faster than 0.1 jobs/s);
* ``burn`` — the named SLO's error-budget burn rate over ``window_s``
  (see :meth:`~repro.obs.slo.SLOTracker.burn_rate`) compares true against
  ``target`` (the burn *factor*; pair a fast small-window rule with a slow
  large-window one for the classic multi-window burn alert).

Lifecycle per rule — ``PENDING`` → ``FIRING`` → ``RESOLVED``:

* a true condition arms the rule as PENDING (stamped at the first true
  sample); it must *stay* true for ``for_s`` virtual seconds before the
  rule fires — a flapping series keeps re-arming and never fires;
* once FIRING, the rule stays firing without re-notifying while the
  condition holds (a sustained breach fires exactly once) and resolves on
  the first false evaluation.

Every lifecycle transition lands in the bound trace as an ``alert`` event
(kind/severity/value in the args), so firings are visible in the Perfetto
export and to the campaign doctor; :class:`AlertIncident` keeps the
fired→resolved intervals for reports and dashboards — and for the
autoscaling layer the roadmap points at, which should consume
:attr:`AlertEngine.incidents` / :meth:`AlertEngine.firing` rather than
re-deriving breaches from raw series.

Cold-side module: hot loops never import this (``tools/check_obs_imports``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "PENDING",
    "FIRING",
    "RESOLVED",
    "AlertRule",
    "AlertIncident",
    "AlertEngine",
    "format_alerts",
]

#: Lifecycle states (the INACTIVE ground state is implicit).
PENDING = "pending"
FIRING = "firing"
RESOLVED = "resolved"
_INACTIVE = "inactive"

_KINDS = ("threshold", "rate", "burn")
_OPS = ("<=", ">=")
_SEVERITIES = ("info", "warning", "critical")


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One declarative alerting rule over hub series / SLO burn rates."""

    name: str
    kind: str = "threshold"
    series: Optional[str] = None     # threshold / rate source
    slo: Optional[str] = None        # burn source (SLOTracker spec name)
    op: str = ">="
    target: float = 0.0
    for_s: float = 0.0               # hysteresis: condition must hold this long
    window_s: float = 300.0          # rate lookback / burn window
    severity: str = "warning"
    description: str = ""

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(
                f"alert {self.name!r}: kind must be one of {_KINDS}"
            )
        if self.op not in _OPS:
            raise ValueError(f"alert {self.name!r}: op must be one of {_OPS}")
        if self.severity not in _SEVERITIES:
            raise ValueError(
                f"alert {self.name!r}: severity must be one of {_SEVERITIES}"
            )
        if self.kind == "burn":
            if self.slo is None:
                raise ValueError(f"alert {self.name!r}: burn rules need slo=")
        elif self.series is None:
            raise ValueError(
                f"alert {self.name!r}: {self.kind} rules need series="
            )
        if self.for_s < 0 or self.window_s <= 0:
            raise ValueError(
                f"alert {self.name!r}: for_s must be >= 0 and window_s > 0"
            )


@dataclasses.dataclass
class AlertIncident:
    """One fired alert: the FIRING → RESOLVED interval (``t_resolved`` is
    ``None`` while still firing)."""

    rule: str
    severity: str
    t_pending: float
    t_fired: float
    t_resolved: Optional[float] = None
    value_at_fire: Optional[float] = None

    @property
    def open(self) -> bool:
        return self.t_resolved is None


class _RuleState:
    __slots__ = ("state", "pending_since", "incident")

    def __init__(self):
        self.state = _INACTIVE
        self.pending_since: Optional[float] = None
        self.incident: Optional[AlertIncident] = None


class AlertEngine:
    """Evaluates :class:`AlertRule` sets against one
    :class:`~repro.obs.metrics.MetricsHub` (and optional
    :class:`~repro.obs.slo.SLOTracker` for burn rules — the tracker's
    compliance sampling is driven from here too, so attaching the engine is
    all the wiring SLO accounting needs).

    Attach to a recorder either at construction
    (``TraceRecorder(metrics=hub, alerts=engine)``) or with
    :meth:`attach`; the recorder then calls :meth:`evaluate` at its
    metronome sample cadence.
    """

    def __init__(self, hub, rules=(), *, slos=None):
        self.hub = hub
        self.rules: tuple[AlertRule, ...] = tuple(rules)
        names = [r.name for r in self.rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate alert rule names in {names}")
        self.slos = slos
        for r in self.rules:
            if r.kind == "burn":
                if slos is None:
                    raise ValueError(
                        f"alert {r.name!r} is a burn rule but no slos= "
                        "tracker was attached"
                    )
                slos._spec(r.slo)           # fail fast on unknown SLO names
        self._state = {r.name: _RuleState() for r in self.rules}
        #: every incident that ever fired, in firing order
        self.incidents: list[AlertIncident] = []
        #: PENDING arms that cleared before ``for_s`` elapsed (flaps)
        self.pending_cancelled = 0
        self.evaluations = 0

    def attach(self, recorder) -> "AlertEngine":
        """Install on a recorder post-construction; returns self."""
        recorder.alerts = self
        return self

    # -- conditions -----------------------------------------------------------
    def _value(self, rule: AlertRule, t: float) -> Optional[float]:
        if rule.kind == "burn":
            return self.slos.burn_rate(rule.slo, rule.window_s, t)
        s = self.hub.series.get(rule.series)
        if s is None or len(s) == 0:
            return None
        if rule.kind == "threshold":
            return s.last()[1]
        # rate: average slope over the trailing window — needs a sample at
        # or before the window start, else the lookback isn't covered yet
        t_now, v_now = s.last()
        past = s.window(None, t_now - rule.window_s)
        if not past:
            return None
        t_then, v_then = past[-1]
        if t_now <= t_then:
            return None
        return (v_now - v_then) / (t_now - t_then)

    def _condition(self, rule: AlertRule, t: float) -> tuple[bool, Optional[float]]:
        v = self._value(rule, t)
        if v is None:
            return False, None
        ok = (v <= rule.target) if rule.op == "<=" else (v >= rule.target)
        return ok, v

    # -- evaluation (called from TraceRecorder._tick) -------------------------
    def evaluate(self, t: float, trace=None) -> None:
        """One metronome tick: sample SLO compliance, then run every rule's
        state machine. ``trace`` (the bound recorder) receives the
        lifecycle transition events."""
        self.evaluations += 1
        if self.slos is not None:
            self.slos.observe(t, trace)
        for rule in self.rules:
            st = self._state[rule.name]
            cond, value = self._condition(rule, t)
            if cond:
                if st.state == _INACTIVE:
                    st.pending_since = t
                    if rule.for_s > 0.0:
                        st.state = PENDING
                        self._event(trace, t, rule, PENDING, value)
                        continue
                    self._fire(trace, t, rule, st, value)
                elif st.state == PENDING and t - st.pending_since >= rule.for_s:
                    self._fire(trace, t, rule, st, value)
            else:
                if st.state == PENDING:
                    st.state = _INACTIVE
                    st.pending_since = None
                    self.pending_cancelled += 1
                elif st.state == FIRING:
                    st.state = _INACTIVE
                    st.pending_since = None
                    st.incident.t_resolved = t
                    st.incident = None
                    self._event(trace, t, rule, RESOLVED, value)

    def _fire(self, trace, t, rule, st, value) -> None:
        st.state = FIRING
        st.incident = AlertIncident(
            rule=rule.name,
            severity=rule.severity,
            t_pending=st.pending_since,
            t_fired=t,
            value_at_fire=value,
        )
        self.incidents.append(st.incident)
        self._event(trace, t, rule, FIRING, value)

    def _event(self, trace, t, rule, state, value) -> None:
        if trace is None or not trace.enabled:
            return
        trace.events.append(
            (
                "alert",
                t,
                rule.name,
                {
                    "state": state,
                    "kind": rule.kind,
                    "severity": rule.severity,
                    "value": value,
                    "target": rule.target,
                },
            )
        )

    # -- introspection --------------------------------------------------------
    def state(self, name: str) -> str:
        """Current lifecycle state of one rule (``inactive`` when quiet)."""
        return self._state[name].state

    def firing(self) -> tuple[str, ...]:
        """Names of the rules currently FIRING."""
        return tuple(r.name for r in self.rules
                     if self._state[r.name].state == FIRING)

    def incidents_for(self, name: str) -> list[AlertIncident]:
        return [i for i in self.incidents if i.rule == name]


def format_alerts(engine: AlertEngine) -> str:
    """Terminal summary: per-rule state plus the incident log."""
    lines = [
        f"alerts: {len(engine.rules)} rules, {len(engine.incidents)} "
        f"incidents, {engine.pending_cancelled} flaps suppressed, "
        f"{engine.evaluations} evaluations"
    ]
    for rule in engine.rules:
        lines.append(
            f"  {rule.name:<24} [{rule.severity}] {engine.state(rule.name):<9}"
            f" {rule.kind} {rule.series or rule.slo} {rule.op} {rule.target:g}"
            + (f" for {rule.for_s:g}s" if rule.for_s else "")
        )
    for inc in engine.incidents:
        end = f"{inc.t_resolved:,.1f}s" if inc.t_resolved is not None else "still firing"
        lines.append(
            f"    fired {inc.rule} [{inc.severity}] at {inc.t_fired:,.1f}s "
            f"(pending from {inc.t_pending:,.1f}s) -> {end}"
        )
    return "\n".join(lines)
