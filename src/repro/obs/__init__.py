"""Observability: campaign tracing, time-series metrics, trace exporters,
and critical-path profiling.

Opt-in by construction: every hot component defaults to the shared no-op
:data:`~repro.obs.trace.NULL_RECORDER`, and the only obs module the hot
loops may import is :mod:`repro.obs.trace` (the recorder interface —
``tools/check_obs_imports.py`` guards this). Turning tracing on is one
argument::

    from repro.obs import MetricsHub, TraceRecorder

    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub, sample_every_s=120.0)
    orch = Orchestrator(cluster, recorder=rec)
    orch.run_campaign(specs)

    from repro.obs.export import write_chrome_trace
    from repro.obs.profile import critical_path, format_critical_path

    write_chrome_trace("trace.json", rec, hub)     # open in Perfetto
    print(format_critical_path(critical_path(rec)))
"""

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsHub,
    TimeSeries,
)
from .trace import NULL_RECORDER, NullRecorder, TraceRecorder
from .export import chrome_trace, jsonl_records, write_chrome_trace, write_jsonl
from .profile import (
    CriticalPath,
    PathSegment,
    critical_path,
    format_critical_path,
)
from .slo import SLOReport, SLOSpec, SLOStatus, SLOTracker, format_slo_report
from .alerts import (
    FIRING,
    PENDING,
    RESOLVED,
    AlertEngine,
    AlertIncident,
    AlertRule,
    format_alerts,
)
from .diagnose import Advisory, diagnose, format_advisories
from .dashboard import build_dashboard, format_dashboard, write_dashboard

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsHub",
    "TimeSeries",
    "NULL_RECORDER",
    "NullRecorder",
    "TraceRecorder",
    "chrome_trace",
    "jsonl_records",
    "write_chrome_trace",
    "write_jsonl",
    "CriticalPath",
    "PathSegment",
    "critical_path",
    "format_critical_path",
    "SLOSpec",
    "SLOStatus",
    "SLOReport",
    "SLOTracker",
    "format_slo_report",
    "PENDING",
    "FIRING",
    "RESOLVED",
    "AlertRule",
    "AlertIncident",
    "AlertEngine",
    "format_alerts",
    "Advisory",
    "diagnose",
    "format_advisories",
    "build_dashboard",
    "write_dashboard",
    "format_dashboard",
]
