"""SLO-guarded campaign: objectives, a mid-campaign fault burst, alerts,
the campaign doctor, and a self-contained HTML dashboard.

A 100-job stage-in-heavy campaign runs on dom's 8+4 nodes with the full
PR 7 active observability layer attached:

* four :class:`~repro.obs.SLOSpec` objectives — queue-delay p99 (over the
  trace's per-phase histogram), queue-depth p95 (windowed series
  quantile), stage-in cache hit-rate floor, and a compute-utilization
  floor — accounted per metronome sample on the **virtual** clock;
* an :class:`~repro.obs.AlertEngine` with threshold, rate-of-change, and
  SLO burn-rate rules. Midway through the campaign a fault burst is
  injected (the stage-in failure probability jumps for 10 virtual
  minutes): the failed-job growth-rate alert must trip, then resolve when
  the burst passes;
* the campaign doctor (:func:`~repro.obs.diagnose`), which must identify
  the campaign as **stage-in bound** (the specs stage tens of GB per job
  against a 4-node storage partition on purpose);
* :func:`~repro.obs.write_dashboard` — a single static HTML file with
  inline SVG sparklines, the SLO/error-budget table, the alert timeline,
  and the doctor's advisories: no scripts, no external requests.

The script asserts each of those outcomes, so it doubles as an
integration check in CI.

Run:  PYTHONPATH=src python examples/slo_campaign.py
"""

import os

from repro.core import dom_cluster
from repro.obs import (
    AlertEngine,
    AlertRule,
    MetricsHub,
    SLOSpec,
    SLOTracker,
    TraceRecorder,
    diagnose,
    format_dashboard,
    write_dashboard,
)
from repro.orchestrator import (
    BackfillPolicy,
    Orchestrator,
    WorkflowSpec,
    format_report,
    poisson_arrivals,
    summarize,
)
from repro.provision import StorageSpec
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9
N_JOBS = 100
BURST_T0, BURST_T1 = 500.0, 1_100.0        # virtual fault-burst window
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "out")
DASHBOARD = os.path.join(OUT_DIR, "slo_dashboard.html")

CALM = FaultSpec(stage_in_fail_p=0.01, seed=7)
BURST = FaultSpec(stage_in_fail_p=0.85, run_fail_p=0.3, seed=7)


def make_specs():
    """Stage-in-heavy ephemeral jobs: tens of GB in, a short compute burst
    out — the shape that makes a campaign stage-in bound. Every other job
    is no-retry, so a fault during the burst is a terminal failure the
    ``jobs_failed`` rate alert can see (retried jobs just re-queue and
    land after the burst has passed)."""
    return [
        WorkflowSpec(
            name=f"ingest{i:03d}",
            n_compute=1 + i % 2,
            storage_spec=StorageSpec(
                f"ingest{i:03d}",
                nodes=1 + i % 2,
                stage_in_bytes=(100.0 + 20.0 * (i % 3)) * GB,
                stage_out_bytes=2.0 * GB,
            ),
            run_time_s=12.0 + 3.0 * (i % 4),
            max_retries=0 if i % 2 == 0 else 2,
        )
        for i in range(N_JOBS)
    ]


def make_slos(hub):
    return SLOTracker(
        hub,
        [
            SLOSpec(
                name="queue-delay-p99",
                histogram="phase_s/queued",
                percentile=0.99,
                op="<=",
                target=2_500.0,
                objective=0.75,
                burn_windows=(600.0, 3600.0),
                description="p99 time-in-queue stays under ~42 min",
            ),
            SLOSpec(
                name="queue-depth-p95",
                series="queue_depth",
                percentile=0.95,
                window_s=900.0,
                op="<=",
                target=95.0,
                objective=0.9,
                description="windowed p95 backlog stays bounded",
            ),
            SLOSpec(
                name="stage-in-hit-rate",
                series="catalog_hit_rate",
                op=">=",
                target=0.25,
                objective=0.5,
                description="a quarter of dataset lookups should be warm",
            ),
            SLOSpec(
                name="compute-utilization",
                series="free_compute_nodes",
                op="<=",
                target=7.0,
                objective=0.6,
                description="at least one compute node is busy mid-campaign",
            ),
        ],
    )


def make_alerts(hub, slos):
    return AlertEngine(
        hub,
        [
            AlertRule(
                name="failed-jobs-growth",
                kind="rate",
                series="jobs_failed",
                op=">=",
                target=0.008,               # jobs failing per virtual second
                window_s=240.0,
                severity="critical",
                description="terminal failures are accumulating",
            ),
            AlertRule(
                name="queue-backlog",
                kind="threshold",
                series="queue_depth",
                op=">=",
                target=85.0,
                for_s=240.0,
                severity="warning",
            ),
            AlertRule(
                name="queue-delay-burn",
                kind="burn",
                slo="queue-delay-p99",
                op=">=",
                target=4.0,                 # 4x sustainable budget spend
                window_s=600.0,
                severity="critical",
            ),
        ],
        slos=slos,
    )


def main() -> None:
    cluster = dom_cluster()
    hub = MetricsHub()
    slos = make_slos(hub)
    alerts = make_alerts(hub, slos)
    rec = TraceRecorder(metrics=hub, sample_every_s=30.0, alerts=alerts)
    orch = Orchestrator(
        cluster,
        policy=BackfillPolicy(),
        faults=FaultInjector(CALM),
        recorder=rec,
    )
    # a small campaign under-runs the 512-event metronome stride; sample
    # often enough that the alert engine sees the burst while it is live
    orch.engine.SAMPLE_EVERY = 32

    # -- run with a fault burst injected mid-campaign -------------------------
    arrivals = poisson_arrivals(rate_per_s=0.25, n=N_JOBS, seed=7)
    orch.run_campaign(make_specs(), submit_times=arrivals, until=BURST_T0)
    orch.faults = FaultInjector(BURST)      # swap injectors on the live run
    orch.run_campaign(until=BURST_T1)
    orch.faults = FaultInjector(CALM)
    jobs = orch.run_campaign()              # drain to completion

    report = summarize(
        jobs, n_storage_nodes=len(cluster.storage_nodes), trace=rec
    )
    print(format_report(report, top_n=3))
    print()

    # -- the fault burst must have tripped (and resolved) the rate alert ------
    incidents = alerts.incidents_for("failed-jobs-growth")
    assert incidents, "fault burst never tripped the failed-jobs-growth alert"
    first = incidents[0]
    assert first.t_fired >= BURST_T0, (
        f"alert fired at {first.t_fired:.0f}s, before the burst began"
    )
    assert not first.open, "alert never resolved after the burst passed"
    alert_events = [e for e in rec.events if e[0] == "alert"]
    assert alert_events, "alert lifecycle transitions missing from the trace"

    # -- SLO accounting rode the virtual clock --------------------------------
    assert report.slo is not None and slos.samples_taken == alerts.evaluations
    assert report.slo.status("stage-in-hit-rate").breached, (
        "no pools are attached, so the hit-rate SLO must be breached"
    )

    # -- the doctor must call the campaign stage-in bound ---------------------
    advisories = diagnose(rec, report=report)
    codes = [a.code for a in advisories]
    assert "stage_in_bound" in codes, f"doctor said {codes}"
    top_structural = next(a for a in advisories if a.code != "slo_breach")
    assert top_structural.code == "stage_in_bound", (
        f"top structural advisory was {top_structural.code}"
    )

    # -- dashboard: one file, zero external requests, no scripts --------------
    os.makedirs(OUT_DIR, exist_ok=True)
    write_dashboard(DASHBOARD, rec, report=report, advisories=advisories,
                    title="SLO campaign, dom 8+4")
    with open(DASHBOARD, encoding="utf-8") as fh:
        doc = fh.read()
    low = doc.lower()
    assert low.startswith("<!doctype html>")
    assert "<script" not in low, "dashboard must not carry scripts"
    assert "http" not in low, "dashboard must not reference the network"
    assert "<svg" in low and "slo" in low

    print(format_dashboard(rec, report=report, advisories=advisories))
    print()
    print(f"dashboard    : {DASHBOARD} ({len(doc):,} bytes, self-contained)")
    print(f"alerts       : {len(alerts.incidents)} incidents, "
          f"{alerts.pending_cancelled} flaps suppressed, "
          f"{alerts.evaluations} evaluations")
    print(f"top advisory : {advisories[0]}")


if __name__ == "__main__":
    main()
