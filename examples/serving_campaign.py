"""Serving campaign: a flash crowd, an alert, an autoscaler, a drain.

A pool-backed model fleet serves a diurnal request trace on dom's 8+4
nodes. Midway through, a traffic burst overwhelms the single warm replica:

1. model weights (28 GB) stage **once** into a PERSISTENT pool; the
   replica attaches a POOLED lease and pages them in (every later attach
   is a pure catalog hit — asserted from the trace);
2. the burst builds a queue; the ``queue-delay`` SLO starts burning error
   budget and the ``queue-delay-burn`` alert goes FIRING;
3. the :class:`~repro.serving.Autoscaler` consumes the incident and
   scales up — warm lease attach + perfmodel-priced page-in, no deploy;
4. the backlog clears, the alert RESOLVES, and idle-TTL drains the fleet
   back to one replica (the pool keeps the weights resident);
5. the campaign doctor reads the span-free serving trace and the HTML
   dashboard renders it — script-free, network-free.

The script asserts each outcome, so it doubles as a CI integration check.

Run:  PYTHONPATH=src python examples/serving_campaign.py
"""

import os

from repro.core import dom_cluster
from repro.obs import (
    AlertEngine,
    AlertRule,
    MetricsHub,
    SLOSpec,
    SLOTracker,
    TraceRecorder,
    diagnose,
    write_dashboard,
)
from repro.orchestrator import burst_arrivals, diurnal_arrivals
from repro.serving import (
    Autoscaler,
    AutoscalerConfig,
    ModelProfile,
    ServingCampaign,
    format_serving_report,
    synthesize_requests,
)

GB = 1e9
BURST_T0, BURST_T1 = 400.0, 520.0
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "out")
DASHBOARD = os.path.join(OUT_DIR, "serving_dashboard.html")


def main() -> None:
    times = sorted(
        diurnal_arrivals(500, base_rate=0.4, peak_rate=1.6,
                         period_s=1_200.0, seed=11)
        + burst_arrivals(220, base_rate=0.05, burst_rate=6.0,
                         burst_t0=BURST_T0, burst_t1=BURST_T1, seed=12)
    )
    requests = synthesize_requests(times, seed=13)
    model = ModelProfile("qwen3-14b-sim", weight_bytes=28 * GB, n_slots=8)

    hub = MetricsHub()
    slos = SLOTracker(
        hub,
        [
            SLOSpec(
                name="queue-delay",
                series="serving/queue_delay_s",
                op="<=",
                target=2.0,
                objective=0.85,
                burn_windows=(120.0, 600.0),
                description="head-of-queue wait stays bounded",
            )
        ],
    )
    alerts = AlertEngine(
        hub,
        [
            AlertRule(
                name="queue-delay-burn",
                kind="burn",
                slo="queue-delay",
                op=">=",
                target=3.0,
                window_s=120.0,
                severity="critical",
                description="queue-delay error budget burning 3x too fast",
            )
        ],
        slos=slos,
    )
    rec = TraceRecorder(metrics=hub, sample_every_s=10.0, alerts=alerts)
    autoscaler = Autoscaler(
        alerts,
        AutoscalerConfig(
            rule="queue-delay-burn",
            min_replicas=1,
            max_replicas=4,
            control_every_s=15.0,
            scale_up_cooldown_s=60.0,
            idle_ttl_s=90.0,
        ),
        recorder=rec,
    )
    camp = ServingCampaign(
        dom_cluster(), model, requests,
        initial_replicas=1, autoscaler=autoscaler, recorder=rec,
    )
    report = camp.run()
    print(format_serving_report(report))
    print()

    # -- the burst must have tripped (and resolved) the burn alert ------------
    incidents = alerts.incidents_for("queue-delay-burn")
    assert incidents, "burst never tripped the queue-delay-burn alert"
    first = incidents[0]
    assert first.t_fired >= BURST_T0, (
        f"alert fired at {first.t_fired:.0f}s, before the burst began"
    )
    assert not first.open, "alert never resolved after the backlog cleared"

    # -- the autoscaler consumed the incident: grow, then drain ---------------
    assert report.scale_ups >= 1, "FIRING alert never scaled the fleet up"
    assert report.scale_downs >= 1, "RESOLVED + idle TTL never drained"
    assert report.n_replicas_final == 1, (
        f"fleet ended at {report.n_replicas_final} replicas, expected 1"
    )
    actions = [e[1] for e in camp.rset.scale_events if e[1] in ("up", "down")]
    assert actions.index("up") < len(actions) - 1 - actions[::-1].index("down")

    # -- weights staged exactly once; replica attaches are warm ---------------
    attaches = [e for e in rec.events if e[0] == "lease_attached"]
    misses = [e for e in attaches if e[3]["misses"] > 0]
    assert len(misses) == 1 and misses[0][2] == "serving-weights", (
        f"expected exactly the loader lease to miss, got {misses}"
    )
    pm = camp.service.pool_manager
    assert pm.stats.bytes_staged == model.weight_bytes

    # -- every request served -------------------------------------------------
    assert report.n_completed == len(requests)

    # -- doctor reads the span-free serving trace -----------------------------
    advisories = diagnose(rec)
    codes = [a.code for a in advisories]
    assert "serving_queue_bound" in codes, f"doctor said {codes}"

    # -- dashboard: one file, zero external requests, no scripts --------------
    os.makedirs(OUT_DIR, exist_ok=True)
    write_dashboard(DASHBOARD, rec, advisories=advisories,
                    title="Serving campaign, dom 8+4")
    with open(DASHBOARD, encoding="utf-8") as fh:
        doc = fh.read()
    low = doc.lower()
    assert low.startswith("<!doctype html>")
    assert "<script" not in low, "dashboard must not carry scripts"
    assert "http" not in low, "dashboard must not reference the network"

    print(f"alert        : fired {first.t_fired:,.0f}s, "
          f"resolved {first.t_resolved:,.0f}s "
          f"(burst was [{BURST_T0:,.0f}, {BURST_T1:,.0f}]s)")
    print(f"fleet        : {report.scale_ups} up / {report.scale_downs} down, "
          f"peak {report.peak_replicas}, "
          f"{report.replica_seconds:,.0f} replica-seconds")
    print(f"weights      : staged once ({model.weight_bytes / GB:.0f} GB), "
          f"{len(attaches) - 1} warm replica attaches")
    print(f"top advisory : {advisories[0]}")
    print(f"dashboard    : {DASHBOARD} ({len(doc):,} bytes, self-contained)")


if __name__ == "__main__":
    main()
