"""Traced campaign: record everything, export a Perfetto timeline, and
profile the critical path.

A mixed 80-job campaign on dom's 8+4 nodes — pooled shared-dataset
analysis jobs, ephemeral-FS simulations with checkpoint commits, and a
seeded fault injector tripping staging/run attempts — runs with a
:class:`~repro.obs.TraceRecorder` and :class:`~repro.obs.MetricsHub`
attached. The trace lands in three forms:

* ``benchmarks/out/trace_campaign.json`` — Chrome trace-event JSON; open
  it at https://ui.perfetto.dev (one track per job / backend / pool,
  spans per lifecycle phase, flow arrows on fault->requeue, counter
  tracks from the metrics hub);
* ``benchmarks/out/trace_campaign.jsonl`` — one flat record per span /
  session / event for ad-hoc ``jq``-style analysis;
* stdout — the campaign report with the critical-path breakdown:
  which phases the makespan was actually spent on, walked backward
  through the grant-enablement chain.

The script asserts what the PR 6 acceptance requires: the export is
valid JSON, and the critical-path phase totals sum to the makespan
exactly.

Run:  PYTHONPATH=src python examples/trace_campaign.py
"""

import json
import os
import time

from repro.core import dom_cluster
from repro.obs import (
    MetricsHub,
    TraceRecorder,
    write_chrome_trace,
    write_jsonl,
)
from repro.orchestrator import (
    BackfillPolicy,
    Orchestrator,
    WorkflowSpec,
    format_report,
    poisson_arrivals,
    summarize,
)
from repro.pool import DatasetRef
from repro.provision import LifetimeClass, StorageSpec
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9
N_JOBS = 80
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "out")


def make_specs(datasets):
    """Pooled analysis + checkpointing simulations + KV feature jobs."""
    specs = []
    for i in range(N_JOBS):
        kind = i % 5
        name = f"job{i:03d}"
        if kind < 2:        # pooled shared-dataset analysis
            spec = WorkflowSpec(
                name=name,
                n_compute=1 + i % 2,
                storage_spec=StorageSpec(
                    name,
                    lifetime=LifetimeClass.POOLED,
                    datasets=(datasets[i % len(datasets)],),
                    stage_in_bytes=2 * GB,
                    stage_out_bytes=1 * GB,
                ),
                run_time_s=30.0 + 10.0 * (i % 3),
            )
        elif kind < 4:      # checkpoint-heavy ephemeral-FS simulation
            spec = WorkflowSpec(
                name=name,
                n_compute=2 + i % 3,
                storage_spec=StorageSpec(
                    name,
                    nodes=1 + i % 2,
                    managers=("ephemeralfs",),
                    stage_in_bytes=30 * GB,
                    stage_out_bytes=10 * GB,
                ),
                run_time_s=120.0 + 20.0 * (i % 4),
                max_retries=3,
                checkpoint_every_s=40.0,
                checkpoint_bytes=2 * GB,
            )
        else:               # feature extraction into the KV store
            spec = WorkflowSpec(
                name=name,
                n_compute=1,
                storage_spec=StorageSpec(
                    name,
                    nodes=1,
                    access="kv",
                    stage_in_bytes=6 * GB,
                ),
                run_time_s=45.0,
            )
        specs.append(spec)
    return specs


def main() -> None:
    cluster = dom_cluster()
    datasets = [DatasetRef(f"tile{k}", (15.0 + 5.0 * k) * GB) for k in range(4)]

    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub, sample_every_s=60.0)
    orch = Orchestrator(
        cluster,
        policy=BackfillPolicy(),
        faults=FaultInjector(
            FaultSpec(stage_in_fail_p=0.04, run_fail_p=0.03, seed=11)
        ),
        recorder=rec,
    )
    orch.enable_pools(ttl_s=1500.0)
    for k in range(2):      # persistent pools backing the POOLED jobs
        orch.provision.open_session(
            StorageSpec(
                f"tile-pool{k}",
                nodes=1,
                lifetime=LifetimeClass.PERSISTENT,
                capacity_cap_bytes=80.0 * GB,
            )
        )
    # a short campaign never reaches the default 512-event metronome
    # stride; sample often enough for visible counter tracks
    orch.engine.SAMPLE_EVERY = 64

    t0 = time.perf_counter()
    jobs = orch.run_campaign(
        make_specs(datasets),
        submit_times=poisson_arrivals(rate_per_s=0.4, n=N_JOBS, seed=11),
    )
    wall = time.perf_counter() - t0

    # -- report + critical path (summarize folds the trace in) ---------------
    rep = summarize(jobs, n_storage_nodes=len(cluster.storage_nodes),
                    pools=orch.pools, trace=rec)
    print(f"=== traced campaign (simulated {rep.makespan_s:,.0f} s "
          f"in {wall * 1e3:.0f} ms) ===")
    print(format_report(rep, top_n=3))
    print()

    cp = rep.critical_path
    gap = abs(sum(cp.phase_s.values()) - cp.makespan_s)
    assert gap < 1e-6, f"critical-path phases off makespan by {gap}"

    # -- exports --------------------------------------------------------------
    os.makedirs(OUT_DIR, exist_ok=True)
    trace_path = os.path.join(OUT_DIR, "trace_campaign.json")
    jsonl_path = os.path.join(OUT_DIR, "trace_campaign.jsonl")
    write_chrome_trace(trace_path, rec, metrics=hub)
    write_jsonl(jsonl_path, rec)

    with open(trace_path) as fh:          # the artifact must be valid JSON
        doc = json.load(fh)
    events = doc["traceEvents"]
    assert events and all("ph" in e and "pid" in e for e in events)
    with open(jsonl_path) as fh:
        n_records = sum(1 for line in fh if json.loads(line))

    print(f"chrome trace : {trace_path} ({len(events)} events) "
          f"-- open at https://ui.perfetto.dev")
    print(f"jsonl        : {jsonl_path} ({n_records} records)")
    print(f"trace counts : {dict(sorted(rec.counts.items()))}")
    print(f"metrics      : {hub.samples_taken} samples over "
          f"{len(hub.series)} series")


if __name__ == "__main__":
    main()
