"""Pilot campaign: two-level scheduling — 8 pilots, 40,000 tasks.

A mixed many-task campaign runs through `Orchestrator.submit_pilot`: each
pilot acquires a block of compute nodes plus ONE pooled storage session,
then the in-pilot `TaskScheduler` packs thousands of sub-node tasks into
its slots — wave packing, batch-priced I/O, coalesced completion batches.
A few plain jobs share the cluster to show both levels coexisting. The
PR 10 acceptance walk is asserted end to end:

* **amortized acquisition** — exactly ONE negotiation and ONE pooled
  session per pilot, however many tasks stream through it;
* **packing** — every pilot runs more tasks than it has slots (the
  whole point of the bottom level), and the engine saw orders of
  magnitude fewer events than tasks;
* **task-level fault handling** — task faults retry inside the pilot
  (checkpoint-resumed) without a single global requeue;
* **observability** — per-pilot occupancy series land in the hub and
  the campaign dashboard renders alongside the usual lanes.

The dashboard lands in ``benchmarks/out/pilot_dashboard.html`` — a single
self-contained file, no external requests.

Run:  PYTHONPATH=src python examples/pilot_campaign.py
"""

import os
import time

from repro.core import synthetic_cluster
from repro.obs import MetricsHub, TraceRecorder
from repro.obs.dashboard import write_dashboard
from repro.orchestrator import (
    BackfillPolicy,
    JobState,
    Orchestrator,
    PilotSpec,
    TaskSpec,
    WorkflowSpec,
    format_report,
    summarize,
)
from repro.pool import DatasetRef
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9
N_PILOTS = 8
TASKS_PER_PILOT = 5_000
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "out")


def main() -> None:
    cluster = synthetic_cluster(48, 12)
    datasets = [DatasetRef(f"shard{k}", (10.0 + 3.0 * k) * GB) for k in range(4)]

    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub, sample_every_s=30.0)
    orch = Orchestrator(
        cluster, policy=BackfillPolicy(), recorder=rec,
        faults=FaultInjector(FaultSpec(task_fail_p=0.01, seed=17)),
    )
    orch.engine.SAMPLE_EVERY = 16
    orch.enable_pools(ttl_s=None).create_pool(nodes=4)

    jobs = []
    for i in range(N_PILOTS):
        task = TaskSpec(
            f"map{i}", run_time_s=20.0 + 5.0 * (i % 3), cores=0.125,
            stage_in_bytes=0.05 * GB, checkpoint_every_s=10.0,
        )
        jobs.append(orch.submit_pilot(
            PilotSpec(
                f"pilot{i}", n_compute=4, slots_per_node=8,
                datasets=(datasets[i % len(datasets)],),
                stage_in_bytes=1 * GB, completion_quantum_s=5.0,
            ),
            tasks=((task, TASKS_PER_PILOT),),
            at=i * 10.0,
        ))
    # a few plain jobs interleave on the same cluster: the two levels share
    # one scheduler, one pool subsystem, one report
    for i in range(6):
        jobs.append(orch.submit(WorkflowSpec(
            f"solo{i}", 2, use_pool=True,
            datasets=(datasets[i % len(datasets)],),
            run_time_s=120.0), at=30.0 + i * 20.0))

    t0 = time.perf_counter()
    orch.engine.run()
    wall = time.perf_counter() - t0

    rep = summarize(jobs, n_storage_nodes=len(cluster.storage_nodes),
                    pools=orch.pools, trace=rec)
    print(f"=== pilot campaign (simulated {rep.makespan_s:,.0f} s "
          f"in {wall * 1e3:.0f} ms) ===")
    print(format_report(rep, top_n=3))
    print()

    pilots = [j for j in jobs if j.pilot is not None]
    n_tasks = sum(j.pilot.stats.submitted for j in pilots)

    # -- amortized acquisition: ONE negotiation + ONE session per pilot ------
    n_sessions = rec.counts.get("sessions.opened.ephemeralfs", 0)
    n_negotiations = rec.counts.get("negotiation.scored", 0)
    assert n_sessions == len(jobs), (n_sessions, len(jobs))
    assert n_negotiations == len(jobs), (n_negotiations, len(jobs))
    assert rec.counts.get("pilot.started", 0) == N_PILOTS

    # -- packing: tasks far beyond the slot pool, events far below tasks -----
    for j in pilots:
        assert j.pilot.stats.submitted > j.pilot.tasks.base_slots, (
            f"{j.spec.name} did not pack beyond its slots"
        )
    batches = rec.counts.get("pilot.batches", 0)
    assert batches < n_tasks / 5, (
        f"{batches} completion batches for {n_tasks} tasks — not coalescing"
    )

    # -- task-level faults stayed inside the pilots --------------------------
    retries = sum(j.pilot.stats.retries for j in pilots)
    assert retries > 0, "fault injector never tripped a task"
    assert all(j.attempt == 0 for j in pilots), "a pilot requeued globally"
    assert all(j.state is JobState.DONE for j in jobs), "stragglers left"
    assert rep.tasks_done == n_tasks, (rep.tasks_done, n_tasks)

    # -- observability: per-pilot occupancy series + dashboard ---------------
    occ = hub.series.get("pilot_occupancy/pilot0")
    assert occ is not None and len(occ.items()) > 0

    os.makedirs(OUT_DIR, exist_ok=True)
    dash_path = os.path.join(OUT_DIR, "pilot_dashboard.html")
    write_dashboard(dash_path, rec, metrics=hub, report=rep)
    assert os.path.getsize(dash_path) > 0

    saved = sum(j.pilot.stats.run_s_saved for j in pilots)
    print(f"pilots       : {N_PILOTS} x {TASKS_PER_PILOT:,} tasks "
          f"({n_tasks:,} total, {rep.tasks_done:,} done)")
    print(f"acquisitions : {n_sessions} sessions / {n_negotiations} "
          f"negotiations for {len(jobs)} jobs (1 per job, 0 per task)")
    print(f"batches      : {batches:,} coalesced completion batches "
          f"({n_tasks / max(batches, 1):,.0f} tasks per engine event)")
    print(f"task faults  : {retries} in-pilot retries, "
          f"{saved:,.0f} run-seconds saved by task checkpoints, "
          f"0 global requeues")
    print(f"dashboard    : {dash_path}")


if __name__ == "__main__":
    main()
