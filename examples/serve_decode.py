"""Serving example: batched prefill + decode with a KV cache.

A miniature of the decode_32k dry-run cell, actually executed on CPU with a
reduced config: 8 concurrent requests, one prefill, then token-by-token
batched decode with greedy sampling.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

from repro.compat import JAX_DRIFT_REASON, jax_api_drifted

if jax_api_drifted():
    # same detection tests/conftest.py uses — skip, don't crash, so the
    # example stays CI-registered on containers with drifted jax
    print(f"serve_decode: SKIP — {JAX_DRIFT_REASON}")
    raise SystemExit(0)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_smoke  # noqa: E402
from repro.models import build_model  # noqa: E402

ARCH = "qwen3-14b"
BATCH, PROMPT, GEN = 8, 48, 16

cfg = get_smoke(ARCH)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT), 0,
                             cfg.vocab_size)
S_max = PROMPT + GEN

print(f"[{ARCH}] prefill {BATCH} requests x {PROMPT} tokens ...")
prefill = jax.jit(lambda p, b: model.prefill(p, b, S_max))
t0 = time.perf_counter()
logits, cache = prefill(params, {"tokens": prompts})
logits.block_until_ready()
print(f"prefill: {time.perf_counter() - t0:.2f}s (incl. compile)")

decode = jax.jit(model.decode_step, donate_argnums=(1,))
tok = jnp.argmax(logits, axis=-1)
generated = [tok]
t0 = time.perf_counter()
for i in range(GEN - 1):
    logits, cache = decode(params, cache, {"token": tok})
    tok = jnp.argmax(logits, axis=-1)
    generated.append(tok)
tok.block_until_ready()
dt = time.perf_counter() - t0
out = jnp.stack(generated, axis=1)
print(f"decoded {GEN - 1} steps x {BATCH} seqs in {dt:.2f}s "
      f"({(GEN - 1) * BATCH / dt:.1f} tok/s on CPU, incl. compile)")
print("sample continuation (request 0):", out[0].tolist())
assert out.shape == (BATCH, GEN)
assert int(cache["pos"]) == PROMPT + GEN - 1
print("OK")
