"""End-to-end training driver on dynamically provisioned storage.

Wraps ``repro.launch.train``: allocate + provision, stage the corpus in,
train an LM with burst-tier checkpoints drained to the global FS, then
demonstrate crash-restart (--resume restores the newest committed step).

Any assigned architecture works via --arch (reduced config by default so it
runs on CPU; --full selects the published config for real clusters).

Run:  PYTHONPATH=src python examples/train_lm.py -- --steps 40
      PYTHONPATH=src python examples/train_lm.py -- --arch qwen3-14b --steps 20
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if args and args[0] == "--":
        args = args[1:]
    if not args:
        args = ["--arch", "granite-moe-1b-a400m", "--steps", "30",
                "--batch", "8", "--seq", "128", "--ckpt-every", "10"]
    result = main(args)
    print(f"final: held-batch loss {result['eval_before']:.3f} -> "
          f"{result['eval_after']:.3f}; committed checkpoint steps {result['steps']}")
