"""Quickstart: the paper's core flow in one page, through the storage API.

Declare what the job needs (`StorageSpec`), let the `ProvisioningService`
negotiate a data manager and grant compute + storage in one scheduler pass
(the paper's key move — storage is requested like any constraint-tagged
node), mount the provisioned FS from a compute node, do I/O, inspect the
deployment, release everything by leaving the session.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import Workload, dom_cluster, predict_write
from repro.provision import ProvisioningService, StorageSpec

# 1. a cluster with 8 compute nodes + 4 DataWarp-style storage nodes
service = ProvisioningService(dom_cluster())

# 2. one declarative request: 8 compute nodes co-allocated with 20 TB of
#    burst storage (-> 2 DataWarp nodes), preferred data manager first,
#    fallbacks in order — capacity sizing keeps the shared-FS fallback real
spec = StorageSpec("quickstart", capacity_bytes=20e12,
                   managers=("ephemeralfs", "globalfs"))

with service.open_session(spec, n_compute=8, materialize=True) as session:
    alloc = session.allocation
    print(f"negotiated {session.backend}: {len(alloc.compute_nodes)} compute, "
          f"{[n.node_id for n in session.storage_nodes]} storage")

    # 3. the ephemeral parallel FS is provisioned (1 md : 2 storage disks/node)
    dep = session.deployment
    print(f"deployed {len(dep.fs.services())} services in "
          f"{session.provision_time_s:.2f}s (modeled, C8)")
    for svc in dep.fs.services():
        print(f"  {svc.kind:12s} on {svc.node_id} ({svc.disk_name})")

    # 4. mount from a compute node and do real I/O
    client = session.mount("nid00001")
    client.mkdir("/results")
    client.create("/results/out.bin")
    client.pwrite("/results/out.bin", 0, b"hello burst tier" * 65536)  # 1 MiB
    data = client.pread("/results/out.bin", 0, 16)
    print(f"read back: {data!r}; file striped over "
          f"{client.stat('/results/out.bin').n_targets} targets")

    # 5. what would this deployment sustain at paper scale?
    w = Workload(n_procs=288, size_per_proc=64 << 20, pattern="fpp")
    print(f"modeled file-per-process write: "
          f"{predict_write(w, session.fs_model).peak_bandwidth / 1e9:.2f} GB/s")

# 6. session exit: services killed, data deleted, nodes returned
print("released:", service.scheduler.free_counts())
