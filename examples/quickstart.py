"""Quickstart: the paper's core flow in one page.

Request compute + storage from the scheduler, provision an on-demand
parallel FS on the storage nodes (BeeGFS-analogue), mount it from a compute
node, do I/O, inspect the deployment, release everything.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    JobRequest,
    Provisioner,
    Scheduler,
    StorageRequest,
    Workload,
    dom_cluster,
    predict_write,
)

# 1. a cluster with 8 compute nodes + 4 DataWarp-style storage nodes
cluster = dom_cluster()
scheduler = Scheduler(cluster)

# 2. one job, two allocations: compute AND storage (the paper's key move —
#    storage is requested like any constraint-tagged node)
alloc = scheduler.submit(
    JobRequest("quickstart", n_compute=8, storage=StorageRequest(nodes=2))
)
print(f"granted: {len(alloc.compute_nodes)} compute, "
      f"{[n.node_id for n in alloc.storage_nodes]} storage")

# 3. provision the ephemeral parallel FS (1 metadata : 2 storage disks/node)
prov = Provisioner(cluster)
deployment = prov.deploy(prov.plan_for(alloc))
print(f"deployed {len(deployment.fs.services())} services in "
      f"{deployment.deploy_time_s:.2f}s (modeled, C8)")
for svc in deployment.fs.services():
    print(f"  {svc.kind:12s} on {svc.node_id} ({svc.disk_name})")

# 4. mount from a compute node and do real I/O
client = deployment.mount("nid00001")
client.mkdir("/results")
client.create("/results/out.bin")
client.pwrite("/results/out.bin", 0, b"hello burst tier" * 65536)  # 1 MiB
data = client.pread("/results/out.bin", 0, 16)
print(f"read back: {data!r}; file striped over "
      f"{client.stat('/results/out.bin').n_targets} targets")

# 5. what would this deployment sustain at paper scale?
w = Workload(n_procs=288, size_per_proc=64 << 20, pattern="fpp")
print(f"modeled file-per-process write: "
      f"{predict_write(w, deployment.model).peak_bandwidth / 1e9:.2f} GB/s")

# 6. job ends: services killed, data deleted, nodes returned
deployment.teardown()
scheduler.release(alloc)
print("released:", scheduler.free_counts())
