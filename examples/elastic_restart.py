"""Fault-tolerance walkthrough: heartbeats, straggler detection, node loss,
restart planning, checkpoint restore — the large-scale runnability story in
one script.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.core import dom_cluster
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.provision import Placement, ProvisioningService, StorageSpec
from repro.runtime import (
    HeartbeatMonitor,
    RuntimeConfig,
    TrainState,
    make_train_state,
    make_train_step,
    plan_restart,
)

# -- job setup (mirrored storage: survives a storage-node loss) -------------
svc = ProvisioningService(dom_cluster())
session = svc.open_session(
    StorageSpec("elastic", nodes=2, managers=("ephemeralfs",),
                placement=Placement(mirror=True)),
    n_compute=8,
    materialize=True,
)
alloc = session.allocation
dep = session.deployment
mgr = CheckpointManager(dep.fs)

cfg = get_smoke("phi4-mini-3.8b")
model = build_model(cfg)
rt = RuntimeConfig(remat=None, zero1=False, opt=AdamWConfig(lr=1e-3))
state = make_train_state(model, jax.random.PRNGKey(0), rt)
step_fn = jax.jit(make_train_step(model, rt))
batch = {
    "tokens": jnp.ones((4, 64), jnp.int32),
    "labels": jnp.ones((4, 64), jnp.int32),
}

# -- train with heartbeats ---------------------------------------------------
mon = HeartbeatMonitor([n.node_id for n in alloc.compute_nodes], timeout_s=60)
for step in range(6):
    state, m = step_fn(state, batch)
    for i, n in enumerate(alloc.compute_nodes):
        # node 3 is a straggler: reports 4x step time
        mon.beat(n.node_id, step_time_s=4.0 if i == 3 else 1.0)
    if (step + 1) % 3 == 0:
        mgr.save(step + 1, {"params": state.params, "opt": state.opt})
print("straggler detection:", mon.stragglers())

# -- storage node dies mid-run ------------------------------------------------
victim = session.storage_nodes[1].node_id
dep.fs.kill_node(victim)
print(f"killed {victim}; FS degraded={dep.fs.degraded()} "
      f"(mirrored chunks keep serving)")

# -- plan the restart ---------------------------------------------------------
plan = plan_restart(
    alive_chips=240,                  # lost one host of 16 chips
    model_parallel=16,
    committed_steps=mgr.steps(),
    dropped_nodes=(victim,),
)
print(f"restart plan: mesh {plan.mesh_shape} axes {plan.mesh_axes}, "
      f"restore step {plan.restore_step}")

# -- restore through the degraded (mirrored) storage --------------------------
restored, rstep = mgr.restore({"params": state.params, "opt": state.opt})
state2 = TrainState(restored["params"], restored["opt"], ())
state2, m = step_fn(state2, batch)
print(f"resumed from step {rstep}; next loss {float(m['loss']):.4f}")

session.release()
print("OK")
