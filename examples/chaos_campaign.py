"""Chaos campaign: storage-node kills, degraded mirrors, self-healing pools.

A 40-job campaign (mirrored ephemeral-FS simulations + pooled
shared-dataset analysis) runs under a scripted `NodeFaultModel`: one kill
hits a pool's backing node, one hits nodes under mirrored deployments,
and both repair MTTR later. The walk the PR 9 acceptance demands is
asserted end to end:

* **kill** — both scripted node_down events fire and the scheduler's
  healthy-capacity fraction (the ``availability`` gauge) dips below 1;
* **degraded** — at least one mirrored deployment survives its node loss
  DEGRADED (halved bandwidth) instead of dying;
* **rebuild** — the damaged pool heals (a backfilled spare on the
  `RetryPolicy` backoff, or re-silvered on the node's own repair), its
  ledger capacity restored exactly;
* **resolve** — after the repairs, availability returns to 1.0, every
  job completes, and the campaign dashboard renders the node-outage lane
  alongside the availability sparkline.

The dashboard lands in ``benchmarks/out/chaos_dashboard.html`` — a single
self-contained file, no external requests.

Run:  PYTHONPATH=src python examples/chaos_campaign.py
"""

import os
import time

from repro.chaos import NodeFaultModel, RetryPolicy
from repro.core import synthetic_cluster
from repro.obs import MetricsHub, TraceRecorder
from repro.obs.dashboard import write_dashboard
from repro.orchestrator import (
    BackfillPolicy,
    JobState,
    Orchestrator,
    WorkflowSpec,
    format_report,
    summarize,
)
from repro.pool import DatasetRef
from repro.provision import LifetimeClass, Placement, StorageSpec

GB = 1e9
N_JOBS = 40
MTTR_S = 420.0
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "out")


def make_specs(datasets):
    specs = []
    for i in range(N_JOBS):
        name = f"job{i:03d}"
        if i % 4 == 0:      # pooled shared-dataset analysis
            storage = StorageSpec(
                name,
                lifetime=LifetimeClass.POOLED,
                datasets=(datasets[i % len(datasets)],),
                stage_in_bytes=1 * GB,
                stage_out_bytes=1 * GB,
            )
        else:               # mirrored simulation: survives one node loss
            storage = StorageSpec(
                name,
                nodes=2,
                managers=("ephemeralfs",),
                placement=Placement(mirror=True),
                stage_in_bytes=(8.0 + 2.0 * (i % 5)) * GB,
                stage_out_bytes=2 * GB,
            )
        specs.append(
            WorkflowSpec(
                name,
                1 + i % 4,
                storage_spec=storage,
                run_time_s=90.0 + 15.0 * (i % 4),
                max_retries=5,
            )
        )
    return specs


def main() -> None:
    cluster = synthetic_cluster(24, 8)
    datasets = [DatasetRef(f"tile{k}", (12.0 + 4.0 * k) * GB) for k in range(4)]

    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub, sample_every_s=30.0)
    orch = Orchestrator(cluster, policy=BackfillPolicy(), recorder=rec)
    orch.engine.SAMPLE_EVERY = 16          # short campaign: sample densely
    orch.enable_pools(ttl_s=None)
    pool_session = orch.provision.open_session(
        StorageSpec(
            "tile-pool",
            nodes=2,
            lifetime=LifetimeClass.PERSISTENT,
            capacity_cap_bytes=90.0 * GB,
        )
    )
    pool = pool_session.pool
    pool_node = sorted(pool.storage_node_ids)[1]

    # the chaos schedule: one kill into the pool, one into the mirrored
    # fleet, repairs MTTR later — all bulk-scheduled, fully deterministic
    model = NodeFaultModel(
        [n.node_id for n in cluster.storage_nodes],
        mttr_s=MTTR_S,
        schedule=((180.0, pool_node), (300.0, "sn00005")),
    )
    orch.enable_chaos(model, retry=RetryPolicy(base_s=20.0, seed=9))

    t0 = time.perf_counter()
    jobs = orch.run_campaign(
        make_specs(datasets), submit_times=[i * 4.0 for i in range(N_JOBS)]
    )
    wall = time.perf_counter() - t0

    rep = summarize(jobs, n_storage_nodes=len(cluster.storage_nodes),
                    pools=orch.pools, trace=rec)
    print(f"=== chaos campaign (simulated {rep.makespan_s:,.0f} s "
          f"in {wall * 1e3:.0f} ms) ===")
    print(format_report(rep, top_n=3))
    print()

    # -- kill: both scripted outages fired, availability dipped --------------
    assert rec.counts.get("chaos.node_downs", 0) == 2, rec.counts
    assert rec.counts.get("chaos.node_repairs", 0) == 2, rec.counts
    avail = hub.series["availability"]
    lows = [v for _, v in avail.items() if v < 1.0]
    assert lows and min(lows) <= 0.875, "availability never dipped"

    # -- degraded: a mirrored deployment survived its node loss --------------
    n_degraded = rec.counts.get("chaos.degraded", 0)
    assert n_degraded > 0, "no deployment degraded"

    # -- rebuild: the pool healed and its ledger capacity is whole -----------
    assert rec.counts.get("chaos.rebuilds", 0) >= 1, "pool never rebuilt"
    assert not pool.dead_node_capacity, "pool still degraded at campaign end"

    # -- resolve: full health, every job done --------------------------------
    assert orch.scheduler.healthy_capacity_fraction == 1.0
    assert avail.last()[1] == 1.0, f"availability gauge stuck at {avail.last()}"
    assert all(j.state is JobState.DONE for j in jobs), "stragglers left"

    os.makedirs(OUT_DIR, exist_ok=True)
    dash_path = os.path.join(OUT_DIR, "chaos_dashboard.html")
    write_dashboard(dash_path, rec, metrics=hub, report=rep)
    with open(dash_path) as fh:
        html = fh.read()
    assert "node outages" in html, "dashboard lost the node-event lane"
    assert "availability" in html, "dashboard lost the availability sparkline"

    print(f"node kills   : 2 (pool node {pool_node} at t=180s, "
          f"sn00005 at t=300s; repaired +{MTTR_S:.0f}s)")
    print(f"degraded     : {n_degraded} mirrored deployment(s) survived")
    print(f"rebuilds     : {rec.counts['chaos.rebuilds']} "
          f"(replaced={sorted(pool.replaced_node_ids) or 'repaired in place'})")
    print(f"requeued     : {rec.counts.get('fault.requeued', 0)} attempts "
          f"through checkpoint-resume")
    print(f"availability : dipped to {min(lows):.2f}, recovered to "
          f"{avail.last()[1]:.2f}")
    print(f"dashboard    : {dash_path}")


if __name__ == "__main__":
    main()
