"""Workflow campaign: 150 jobs through the event-driven orchestrator.

The paper's pipeline — allocate compute+storage, deploy the on-demand FS,
stage in, run, stage out, tear down — executed as a *campaign* over the
unified StorageSession API: every job states its storage demand as a
declarative `StorageSpec` (sizing by nodes, capacity, or bandwidth;
preferred data managers with ordered fallbacks; QoS floors), and the
orchestrator's `ProvisioningService` negotiates each one onto the best
feasible backend — the BeeGFS-analogue ephemeral FS, the always-on global
FS (zero deploy latency, shared bandwidth), or the KV store. Jobs queue and
backfill when the 4 DataWarp nodes are busy; a fault injector trips some
provisioning and staging attempts, which requeue and retry with a warm
redeploy. Virtual time advances by perfmodel predictions; wallclock stays
in milliseconds.

Run:  PYTHONPATH=src python examples/workflow_campaign.py
"""

import time

from repro.core import dom_cluster
from repro.orchestrator import (
    BackfillPolicy,
    FIFOPolicy,
    Orchestrator,
    StorageAwarePolicy,
    WorkflowSpec,
    format_report,
    summarize,
)
from repro.provision import QoS, StorageSpec
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9


def make_specs(n_jobs: int = 150) -> list[WorkflowSpec]:
    """A mixed campaign: small analysis jobs, zero-deploy postprocessing,
    KV-backed feature extraction, mid-size simulations, and a few
    storage-hungry checkpoint-heavy runs."""
    specs = []
    for i in range(n_jobs):
        kind = i % 10
        if kind < 4:        # small: capacity-sized with a real global-FS
            spec = WorkflowSpec(  # fallback (capacity fits either backend)
                name=f"analysis{i:03d}",
                n_compute=1 + i % 2,
                storage_spec=StorageSpec(
                    f"analysis{i:03d}",
                    capacity_bytes=5e12,                      # -> 1 node
                    managers=("ephemeralfs", "globalfs"),
                    stage_in_bytes=4 * GB,
                    stage_out_bytes=1 * GB,
                ),
                run_time_s=30.0 + 10.0 * (i % 4),
            )
        elif kind < 6:      # postprocessing: needs storage *now* -> the
            spec = WorkflowSpec(  # zero-deploy shared FS wins negotiation
                name=f"post{i:03d}",
                n_compute=1,
                storage_spec=StorageSpec(
                    f"post{i:03d}",
                    capacity_bytes=1e12,
                    managers=("globalfs", "ephemeralfs"),
                    qos=QoS(max_provision_s=1.0),
                    stage_in_bytes=2 * GB,
                    stage_out_bytes=1 * GB,
                ),
                run_time_s=20.0 + 5.0 * (i % 3),
            )
        elif kind < 7:      # feature extraction into an ephemeral KV store
            spec = WorkflowSpec(
                name=f"features{i:03d}",
                n_compute=2,
                storage_spec=StorageSpec(
                    f"features{i:03d}",
                    nodes=1,
                    access="kv",
                    stage_in_bytes=8 * GB,
                ),
                run_time_s=40.0,
            )
        elif kind < 9:      # medium: capacity-sized request (paper §V)
            spec = WorkflowSpec(
                name=f"sim{i:03d}",
                n_compute=4,
                storage_spec=StorageSpec(
                    f"sim{i:03d}",
                    capacity_bytes=14e12,                     # -> 2 nodes
                    managers=("ephemeralfs",),
                    stage_in_bytes=60 * GB,
                    stage_out_bytes=20 * GB,
                ),
                run_time_s=120.0,
            )
        else:               # large: bandwidth-sized with a QoS floor
            spec = WorkflowSpec(
                name=f"ckpt{i:03d}",
                n_compute=8,
                storage_spec=StorageSpec(
                    f"ckpt{i:03d}",
                    bandwidth=18e9,                           # -> 3 nodes
                    managers=("ephemeralfs",),
                    qos=QoS(min_bandwidth=18e9),
                    stage_in_bytes=200 * GB,
                    stage_out_bytes=120 * GB,
                ),
                run_time_s=300.0,
            )
        specs.append(spec)
    return specs


def main() -> None:
    cluster = dom_cluster()     # 8 compute + 4 DataWarp storage nodes
    faults = lambda: FaultInjector(          # noqa: E731
        FaultSpec(provision_fail_p=0.03, stage_in_fail_p=0.02, run_fail_p=0.01, seed=7)
    )

    for policy in (FIFOPolicy(), BackfillPolicy(), StorageAwarePolicy(aging_s=2000)):
        orch = Orchestrator(cluster, policy=policy, faults=faults())
        t0 = time.perf_counter()
        jobs = orch.run_campaign(make_specs())
        wall = time.perf_counter() - t0
        rep = summarize(jobs, n_storage_nodes=len(cluster.storage_nodes))
        stats = orch.provision.stats
        print(f"=== policy: {policy.name} "
              f"(simulated {rep.makespan_s:,.0f} s in {wall * 1e3:.0f} ms) ===")
        print(format_report(rep, top_n=5))
        by_backend = ", ".join(
            f"{k}={v}" for k, v in sorted(stats.sessions_opened.items())
        )
        print(f"negotiated sessions: {by_backend} "
              f"({stats.negotiations} negotiations, "
              f"{stats.negotiation_wall_s * 1e3:.1f} ms total)")
        print()


if __name__ == "__main__":
    main()
