"""Workflow campaign: 150 jobs through the event-driven orchestrator.

The paper's pipeline — allocate compute+storage, deploy the on-demand FS,
stage in, run, stage out, tear down — executed as a *campaign*: far more
storage demand than the 4 DataWarp nodes can serve at once, so jobs queue
and backfill instead of failing; a fault injector trips some provisioning
and staging attempts, which requeue and retry with a warm redeploy.
Virtual time advances by perfmodel predictions (deploy C8, staging
bandwidth, run time); wallclock stays in milliseconds.

Run:  PYTHONPATH=src python examples/workflow_campaign.py
"""

import time

from repro.core import StorageRequest, dom_cluster
from repro.orchestrator import (
    BackfillPolicy,
    FIFOPolicy,
    Orchestrator,
    StorageAwarePolicy,
    WorkflowSpec,
    format_report,
    summarize,
)
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9


def make_specs(n_jobs: int = 150) -> list[WorkflowSpec]:
    """A mixed campaign: small analysis jobs, mid-size simulations, and a
    few storage-hungry checkpoint-heavy runs."""
    specs = []
    for i in range(n_jobs):
        kind = i % 10
        if kind < 6:        # small: 1 storage node, light staging
            spec = WorkflowSpec(
                name=f"analysis{i:03d}",
                n_compute=1 + i % 2,
                storage=StorageRequest(nodes=1),
                stage_in_bytes=4 * GB,
                stage_out_bytes=1 * GB,
                run_time_s=30.0 + 10.0 * (i % 4),
            )
        elif kind < 9:      # medium: capacity-sized request (paper §V)
            spec = WorkflowSpec(
                name=f"sim{i:03d}",
                n_compute=4,
                storage=StorageRequest(capacity_bytes=14e12),   # -> 2 nodes
                stage_in_bytes=60 * GB,
                stage_out_bytes=20 * GB,
                run_time_s=120.0,
            )
        else:               # large: capability-sized, most of the pool
            spec = WorkflowSpec(
                name=f"ckpt{i:03d}",
                n_compute=8,
                storage=StorageRequest(capability_bw=18e9),     # -> 3 nodes
                stage_in_bytes=200 * GB,
                stage_out_bytes=120 * GB,
                run_time_s=300.0,
            )
        specs.append(spec)
    return specs


def main() -> None:
    cluster = dom_cluster()     # 8 compute + 4 DataWarp storage nodes
    faults = lambda: FaultInjector(          # noqa: E731
        FaultSpec(provision_fail_p=0.03, stage_in_fail_p=0.02, run_fail_p=0.01, seed=7)
    )

    for policy in (FIFOPolicy(), BackfillPolicy(), StorageAwarePolicy(aging_s=2000)):
        orch = Orchestrator(cluster, policy=policy, faults=faults())
        t0 = time.perf_counter()
        jobs = orch.run_campaign(make_specs())
        wall = time.perf_counter() - t0
        rep = summarize(jobs, n_storage_nodes=len(cluster.storage_nodes))
        print(f"=== policy: {policy.name} "
              f"(simulated {rep.makespan_s:,.0f} s in {wall * 1e3:.0f} ms) ===")
        print(format_report(rep, top_n=5))
        print()


if __name__ == "__main__":
    main()
