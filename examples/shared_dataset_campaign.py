"""Shared-dataset campaign: persistent pools vs per-job provisioning.

An oversubscribed campaign — 120 jobs over 8 shared datasets on dom's 4
DataWarp nodes, arriving as a Poisson process — run twice, both through the
unified StorageSession API (`StorageSpec` -> `ProvisioningService`):

* **per-job** (the paper's mechanism): every job's spec has EPHEMERAL
  lifetime — negotiation grants fresh storage nodes, deploys a BeeGFS,
  stages *all* of its input datasets from Lustre, and tears everything down
  at job end. Shared data crosses the wire once per referencing job.
* **pooled + data-aware**: two PERSISTENT sessions create long-lived pools
  that pin the storage nodes once; jobs carry POOLED specs, so negotiation
  resolves them to capacity *leases*, `DataAwarePolicy` routes them to the
  pool already holding their inputs, and stage-in moves only cache misses.
  Capped pool ledgers put the LRU eviction engine under pressure; idle
  pools are reaped after a TTL once the queue drains.

Run:  PYTHONPATH=src python examples/shared_dataset_campaign.py
"""

import time

from repro.core import dom_cluster
from repro.orchestrator import (
    BackfillPolicy,
    DataAwarePolicy,
    Orchestrator,
    WorkflowSpec,
    format_report,
    poisson_arrivals,
    summarize,
)
from repro.pool import DatasetRef
from repro.provision import LifetimeClass, StorageSpec

GB = 1e9
N_JOBS = 120
N_DATASETS = 8


def make_datasets() -> list[DatasetRef]:
    """<= 10 shared datasets, 15-30 GB each (climatology tiles, say)."""
    return [
        DatasetRef(f"tile{k:02d}", (15.0 + 5.0 * (k % 4)) * GB)
        for k in range(N_DATASETS)
    ]


def make_specs(datasets: list[DatasetRef], *, pooled: bool) -> list[WorkflowSpec]:
    specs = []
    for i in range(N_JOBS):
        picks = sorted({i % N_DATASETS, (i * i + 1) % (N_DATASETS // 2)})
        name = f"analysis{i:03d}"
        if pooled:
            storage = StorageSpec(
                name,
                lifetime=LifetimeClass.POOLED,
                datasets=tuple(datasets[k] for k in picks),
                stage_in_bytes=2 * GB,     # private inputs
                stage_out_bytes=1 * GB,    # results
            )
        else:
            storage = StorageSpec(
                name,
                nodes=1 + i % 2,
                managers=("ephemeralfs",),
                datasets=tuple(datasets[k] for k in picks),
                stage_in_bytes=2 * GB,
                stage_out_bytes=1 * GB,
            )
        specs.append(
            WorkflowSpec(
                name=name,
                n_compute=1 + i % 3,
                storage_spec=storage,
                run_time_s=25.0 + 5.0 * (i % 5),
            )
        )
    return specs


def main() -> None:
    cluster = dom_cluster()
    arrivals = poisson_arrivals(rate_per_s=0.5, n=N_JOBS, seed=13)

    # --- per-job provisioning (the paper's job-scoped mechanism) ------------
    datasets = make_datasets()
    base = Orchestrator(cluster, policy=BackfillPolicy())
    t0 = time.perf_counter()
    base_jobs = base.run_campaign(
        make_specs(datasets, pooled=False), submit_times=arrivals
    )
    base_wall = time.perf_counter() - t0
    base_rep = summarize(base_jobs, n_storage_nodes=len(cluster.storage_nodes))
    print(f"=== per-job provisioning (simulated {base_rep.makespan_s:,.0f} s "
          f"in {base_wall * 1e3:.0f} ms) ===")
    print(format_report(base_rep, top_n=3))
    print()

    # --- persistent pools + data-aware routing -------------------------------
    orch = Orchestrator(cluster)
    orch.enable_pools(ttl_s=2000.0)     # idle pools reaped after TTL
    svc = orch.provision
    for k in range(2):
        svc.open_session(
            StorageSpec(
                f"tile-pool{k}",
                nodes=2,
                lifetime=LifetimeClass.PERSISTENT,
                capacity_cap_bytes=110.0 * GB,
            )
        )
    orch.policy = DataAwarePolicy(svc)
    t0 = time.perf_counter()
    jobs = orch.run_campaign(make_specs(datasets, pooled=True),
                             submit_times=arrivals)
    wall = time.perf_counter() - t0
    rep = summarize(jobs, n_storage_nodes=len(cluster.storage_nodes),
                    pools=orch.pools)
    print(f"=== pooled + data-aware (simulated {rep.makespan_s:,.0f} s "
          f"in {wall * 1e3:.0f} ms) ===")
    print(format_report(rep, top_n=3))
    print()

    saved = rep.stage_in_bytes_saved
    print(f"stage-in traffic: {base_rep.staged_in_bytes / GB:,.0f} GB per-job vs "
          f"{rep.staged_in_bytes / GB:,.0f} GB pooled "
          f"({saved / base_rep.staged_in_bytes:.0%} of baseline saved)")
    print(f"makespan: {base_rep.makespan_s:,.0f} s per-job vs "
          f"{rep.makespan_s:,.0f} s pooled")
    print(f"pools left live after TTL reap: {len(orch.pools.live_pools)}")


if __name__ == "__main__":
    main()
