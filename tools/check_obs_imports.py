#!/usr/bin/env python
"""Lint guard: hot-loop packages may only import the recorder interface.

The observability subsystem (``repro.obs``) is layered so that the
simulation hot paths — engine, lifecycle, scheduler, provisioning, pools
— depend on exactly one obs module: ``repro.obs.trace`` (the
``NullRecorder`` / ``TraceRecorder`` duck-type). The heavier cold-side
modules (``obs.metrics``, ``obs.export``, ``obs.profile``, and the PR 7
active layer ``obs.slo`` / ``obs.alerts`` / ``obs.diagnose`` /
``obs.dashboard``) must never become load-bearing for a campaign run;
reports that want them import lazily inside the function that builds the
report.

This script enforces that with the AST, in both directions:

* in every module under the hot packages, a **module-level** (or
  class-level — anything that executes at import time) ``import``/
  ``from ... import`` whose target resolves into ``repro.obs`` is a
  violation unless the target module is exactly ``repro.obs.trace``.
  Function-local imports are exempt — that is the sanctioned lazy
  pattern;
* in every module under ``repro.obs`` itself, an import-time import of
  any *other* ``repro`` package is a violation: obs observes the
  simulation through duck-typed hooks and never depends back on it, so
  the layer can't grow a cycle (and stays deletable).

Exit status 0 when clean, 1 with one ``path:line: message`` per
violation otherwise.

    python tools/check_obs_imports.py [--root src/repro]
"""

from __future__ import annotations

import argparse
import ast
import os
import sys

#: packages whose modules run inside the campaign hot loop (``serving``
#: joined in PR 8: its batch/replica/autoscale steps are heap events on
#: the same virtual clock, so the same layering applies; ``chaos`` joined
#: in PR 9: fault schedules and retry backoff fire as heap events too;
#: ``pilot`` joined in PR 10: task waves pack/complete on the heap at
#: up-to-millions-of-tasks scale, the hottest loop in the repo)
HOT_PACKAGES = (
    "core", "orchestrator", "pilot", "pool", "provision", "serving", "chaos",
)

#: the one obs module import-time code may touch
ALLOWED = "repro.obs.trace"

#: the package the reverse rule guards: obs may import stdlib + itself only
OBS_PACKAGE = "repro.obs"


def _module_package(root: str, path: str) -> str:
    """Dotted package relative imports resolve against: the containing
    package for plain modules, the package itself for an ``__init__.py``;
    ``root`` is the directory that contains ``repro``."""
    rel = os.path.relpath(path, root)
    parts = rel.replace(os.sep, "/").split("/")
    parts[-1] = parts[-1][: -len(".py")]
    if parts[-1] == "__init__":
        parts.pop()
        return ".".join(parts)
    return ".".join(parts[:-1])


def _resolve(node: ast.ImportFrom, package: str) -> str:
    """Absolute dotted module an ``ImportFrom`` targets."""
    if node.level == 0:
        return node.module or ""
    base = package.split(".")
    # level 1 = the current package, each extra level climbs one parent
    if node.level > 1:
        base = base[: -(node.level - 1)]
    if node.module:
        base = base + [node.module]
    return ".".join(base)


def _violations_in(path: str, root: str) -> list[tuple[int, str]]:
    with open(path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=path)
    package = _module_package(root, path)
    found: list[tuple[int, str]] = []

    def scan(body, *, import_time: bool) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue    # lazy imports are the sanctioned pattern
            if isinstance(node, ast.Import):
                if import_time:
                    for alias in node.names:
                        _check(node.lineno, alias.name)
            elif isinstance(node, ast.ImportFrom):
                if import_time:
                    # ``from ..obs import trace`` is flagged too: binding
                    # the right name still executes the package __init__
                    _check(node.lineno, _resolve(node, package))
            elif isinstance(node, (ast.If, ast.Try)):
                scan(ast.iter_child_nodes(node), import_time=import_time)
            elif isinstance(node, ast.ClassDef):
                scan(node.body, import_time=import_time)

    def _check(lineno: int, target: str) -> None:
        if ".obs" not in f".{target}":
            return
        if target == ALLOWED or target.startswith(ALLOWED + "."):
            return
        found.append(
            (
                lineno,
                f"module-level import of '{target}' — hot-loop code may "
                f"only import '{ALLOWED}' at import time (use a "
                f"function-local import for metrics/export/profile)",
            )
        )

    scan(tree.body, import_time=True)
    return found


def _obs_violations_in(path: str, root: str) -> list[tuple[int, str]]:
    """The reverse rule: obs modules may not import the simulation back
    at import time (function-local imports stay exempt, same as above)."""
    with open(path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=path)
    package = _module_package(root, path)
    found: list[tuple[int, str]] = []

    def _check(lineno: int, target: str) -> None:
        if target != "repro" and not target.startswith("repro."):
            return
        if target == OBS_PACKAGE or target.startswith(OBS_PACKAGE + "."):
            return
        found.append(
            (
                lineno,
                f"module-level import of '{target}' from inside repro.obs — "
                "the observability layer reads the simulation through "
                "duck-typed hooks and must not import it back",
            )
        )

    def scan(body) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, ast.Import):
                for alias in node.names:
                    _check(node.lineno, alias.name)
            elif isinstance(node, ast.ImportFrom):
                _check(node.lineno, _resolve(node, package))
            elif isinstance(node, (ast.If, ast.Try)):
                scan(ast.iter_child_nodes(node))
            elif isinstance(node, ast.ClassDef):
                scan(node.body)

    scan(tree.body)
    return found


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--root",
        default=os.path.join(os.path.dirname(__file__), "..", "src"),
        help="directory containing the 'repro' package (default: src/)",
    )
    args = ap.parse_args()
    root = os.path.abspath(args.root)
    n_files = 0
    bad = 0
    for pkg in HOT_PACKAGES:
        pkg_dir = os.path.join(root, "repro", pkg)
        for dirpath, _, filenames in os.walk(pkg_dir):
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                n_files += 1
                for lineno, msg in _violations_in(path, root):
                    rel = os.path.relpath(path, os.path.dirname(root))
                    print(f"{rel}:{lineno}: {msg}")
                    bad += 1
    n_obs = 0
    obs_dir = os.path.join(root, *OBS_PACKAGE.split("."))
    for dirpath, _, filenames in os.walk(obs_dir):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            n_obs += 1
            for lineno, msg in _obs_violations_in(path, root):
                rel = os.path.relpath(path, os.path.dirname(root))
                print(f"{rel}:{lineno}: {msg}")
                bad += 1
    if bad:
        print(f"\n{bad} violation(s) across {n_files} hot-loop "
              f"+ {n_obs} obs modules")
        return 1
    print(f"obs import guard: {n_files} hot-loop modules clean, "
          f"{n_obs} obs modules simulation-free")
    return 0


if __name__ == "__main__":
    sys.exit(main())
